//! Leaf-cell generators: bitcells and peripheral circuit cells.
//!
//! Every generator produces a [`LeafCell`]: a layout [`Cell`] and the
//! matching schematic [`Circuit`] built from the *same* placement loop.
//! LVS therefore passes by construction, but is still independently
//! verified by the real extractor ([`crate::lvs`]) and the cells are
//! DRC-verified against the full `sg40` deck in the tests.
//!
//! Drawing conventions (the extractor's device-recognition contract):
//! * Si transistors: horizontal active strip crossed by one vertical
//!   poly gate; stacked CMOS pairs with a common input share ONE poly
//!   column (standard-cell style); gate pads are poly+contact+metal1 in
//!   the mid zone between device rows.
//! * OS transistors: horizontal oschannel strip crossed by a vertical
//!   osgate; S/D and gate connections are `via2` cuts to metal2 (the OS
//!   device plane sits between M2 and M3, so plain M2 may route *under*
//!   a channel without connecting).
//! * intra-cell nets: vertical metal1 from terminal stubs to horizontal
//!   metal2 tracks (via1 at the junction); power rails are horizontal
//!   metal1 at the cell's top/bottom edges.
//! * bitcells: bitlines are full-height metal2 columns at the cell
//!   edges; wordlines are full-width metal3 rows — both connect across
//!   the array by abutment.

use super::{Cell, Pin, Rect};
use crate::netlist::Circuit;
use crate::tech::{LayerRole, Tech};

/// Layout + schematic pair for one library cell.
#[derive(Debug, Clone)]
pub struct LeafCell {
    pub layout: Cell,
    pub circuit: Circuit,
}

/// Geometry constants derived from the rule deck.
#[derive(Debug, Clone, Copy)]
pub struct Geom {
    pub gate_l: i64,
    pub cont: i64,
    pub cont_enc_active: i64,
    pub cont_enc_m1: i64,
    pub gate_to_cont: i64,
    pub gate_ext: i64,
    pub m1_w: i64,
    pub m2_w: i64,
    pub rail_w: i64,
    /// Full transistor footprint width.
    pub dev_w: i64,
    /// X pitch between adjacent transistors.
    pub dev_pitch: i64,
}

impl Geom {
    pub fn of(tech: &Tech) -> Geom {
        let r = &tech.rules;
        let gate_l = r.layer(LayerRole::Poly).min_width_nm;
        let cont = r.layer(LayerRole::Contact).min_width_nm;
        let cont_enc_active = enc(tech, LayerRole::Active, LayerRole::Contact);
        let cont_enc_m1 = enc(tech, LayerRole::Metal1, LayerRole::Contact);
        let gate_to_cont = r
            .cross_spacings
            .iter()
            .find(|s| {
                (s.a == LayerRole::Poly && s.b == LayerRole::Contact)
                    || (s.b == LayerRole::Poly && s.a == LayerRole::Contact)
            })
            .map(|s| s.space_nm.max(50))
            .unwrap_or(50);
        let m1_w = r.layer(LayerRole::Metal1).min_width_nm;
        let m2_w = r.layer(LayerRole::Metal2).min_width_nm;
        let dev_w = 2 * cont_enc_active + 2 * cont + 2 * gate_to_cont + gate_l;
        Geom {
            gate_l,
            cont,
            cont_enc_active,
            cont_enc_m1,
            gate_to_cont,
            gate_ext: 30,
            m1_w,
            m2_w,
            rail_w: 60,
            dev_w,
            dev_pitch: dev_w + r.layer(LayerRole::Active).min_space_nm,
        }
    }
}

fn enc(tech: &Tech, outer: LayerRole, inner: LayerRole) -> i64 {
    tech.rules
        .enclosures
        .iter()
        .find(|e| e.outer == outer && e.inner == inner)
        .map(|e| e.margin_nm)
        .unwrap_or(0)
}

/// Terminal stub: center of the metal1 landing of a terminal.
#[derive(Debug, Clone, Copy)]
pub struct Stub {
    pub x: i64,
    pub y: i64,
}

/// Transistor terminal stubs after drawing.
#[derive(Debug, Clone, Copy)]
pub struct MosStubs {
    pub s: Stub,
    pub g: Stub,
    pub d: Stub,
    pub w_nm: i64,
}

const PAD: i64 = 80; // poly/m1 gate pad side

/// Draw the S/D half of a Si transistor (active, contacts, m1 stubs,
/// implants, well).  The gate poly is drawn by the caller so pairs can
/// share one column.
fn draw_sd(cell: &mut Cell, tech: &Tech, g: &Geom, x: i64, y: i64, w_nm: i64, pmos: bool) -> (Stub, Stub) {
    draw_sd_off(cell, tech, g, x, y, w_nm, pmos, 0, 0)
}

/// draw_sd with per-terminal vertical contact offsets (bitcells slide
/// contacts along the strip, e.g. so a source pad can merge with an
/// abutting power rail while the drain stays clear of it).
#[allow(clippy::too_many_arguments)]
fn draw_sd_off(
    cell: &mut Cell,
    tech: &Tech,
    g: &Geom,
    x: i64,
    y: i64,
    w_nm: i64,
    pmos: bool,
    s_dy: i64,
    d_dy: i64,
) -> (Stub, Stub) {
    let active = tech.layer(LayerRole::Active);
    let contact = tech.layer(LayerRole::Contact);
    let m1 = tech.layer(LayerRole::Metal1);
    cell.add(Rect::new(active, x, y, x + g.dev_w, y + w_nm));
    let cy = y + w_nm / 2 - g.cont / 2;
    let sx = x + g.cont_enc_active;
    let dx = x + g.dev_w - g.cont_enc_active - g.cont;
    for (cx, cy) in [(sx, cy + s_dy), (dx, cy + d_dy)] {
        cell.add(Rect::new(contact, cx, cy, cx + g.cont, cy + g.cont));
        cell.add(Rect::new(
            m1,
            cx - g.cont_enc_m1,
            cy - g.cont_enc_m1,
            cx + g.cont + g.cont_enc_m1,
            cy + g.cont + g.cont_enc_m1,
        ));
    }
    let cy_s = cy + s_dy;
    let cy_d = cy + d_dy;
    let impl_layer = if pmos { tech.layer(LayerRole::Pimplant) } else { tech.layer(LayerRole::Nimplant) };
    cell.add(Rect::new(impl_layer, x - 20, y - 20, x + g.dev_w + 20, y + w_nm + 20));
    if pmos {
        let nw = tech.layer(LayerRole::Nwell);
        cell.add(Rect::new(nw, x - 100, y - 100, x + g.dev_w + 100, y + w_nm + 100));
    }
    (
        Stub { x: sx + g.cont / 2, y: cy_s + g.cont / 2 },
        Stub { x: dx + g.cont / 2, y: cy_d + g.cont / 2 },
    )
}

/// Poly gate pad (poly + contact + m1) centered at (px, py).
fn gate_pad(cell: &mut Cell, tech: &Tech, g: &Geom, px: i64, py: i64) -> Stub {
    let poly = tech.layer(LayerRole::Poly);
    let contact = tech.layer(LayerRole::Contact);
    let m1 = tech.layer(LayerRole::Metal1);
    cell.add(Rect::new(poly, px - PAD / 2, py - PAD / 2, px + PAD / 2, py + PAD / 2));
    cell.add(Rect::new(contact, px - g.cont / 2, py - g.cont / 2, px + g.cont / 2, py + g.cont / 2));
    cell.add(Rect::new(m1, px - PAD / 2, py - PAD / 2, px + PAD / 2, py + PAD / 2));
    Stub { x: px, y: py }
}

/// Gate placement for [`draw_mos`]: pad center y and x offset from the
/// gate column center.
#[derive(Debug, Clone, Copy)]
pub struct GateAt {
    pub pad_y: i64,
    pub pad_dx: i64,
}

/// Draw a single Si transistor with its own poly column reaching a gate
/// pad at `gate.pad_y` (above or below the channel).
#[allow(clippy::too_many_arguments)]
pub fn draw_mos(
    cell: &mut Cell,
    tech: &Tech,
    g: &Geom,
    x: i64,
    y: i64,
    w_nm: i64,
    pmos: bool,
    gate: GateAt,
) -> MosStubs {
    let poly = tech.layer(LayerRole::Poly);
    let (s, d) = draw_sd(cell, tech, g, x, y, w_nm, pmos);
    let gx0 = x + g.cont_enc_active + g.cont + g.gate_to_cont;
    let gxc = gx0 + g.gate_l / 2;
    // poly column spans channel (+ext) through to the pad
    let lo = (y - g.gate_ext).min(gate.pad_y);
    let hi = (y + w_nm + g.gate_ext).max(gate.pad_y);
    cell.add(Rect::new(poly, gx0, lo, gx0 + g.gate_l, hi));
    // jog to the pad if offset
    if gate.pad_dx != 0 {
        let px = gxc + gate.pad_dx;
        let (jx0, jx1) = if gate.pad_dx < 0 { (px, gxc + g.gate_l / 2) } else { (gx0, px) };
        cell.add(Rect::new(poly, jx0, gate.pad_y - g.gate_l / 2, jx1, gate.pad_y + g.gate_l / 2));
    }
    let gstub = gate_pad(cell, tech, g, gxc + gate.pad_dx, gate.pad_y);
    MosStubs { s, g: gstub, d, w_nm }
}

/// Draw a stacked CMOS pair sharing one poly column (common input).
/// Returns (nmos stubs, pmos stubs); both `.g` point at the shared pad.
#[allow(clippy::too_many_arguments)]
pub fn draw_pair(
    cell: &mut Cell,
    tech: &Tech,
    g: &Geom,
    x: i64,
    y_n: i64,
    w_n: i64,
    y_p: i64,
    w_p: i64,
    pad_y: i64,
    pad_dx: i64,
) -> (MosStubs, MosStubs) {
    draw_pair_off(cell, tech, g, x, y_n, w_n, y_p, w_p, pad_y, pad_dx, 0, 0)
}

/// [`draw_pair`] with pmos S/D contact offsets (see draw_sd_off).
#[allow(clippy::too_many_arguments)]
pub fn draw_pair_off(
    cell: &mut Cell,
    tech: &Tech,
    g: &Geom,
    x: i64,
    y_n: i64,
    w_n: i64,
    y_p: i64,
    w_p: i64,
    pad_y: i64,
    pad_dx: i64,
    p_s_dy: i64,
    p_d_dy: i64,
) -> (MosStubs, MosStubs) {
    let poly = tech.layer(LayerRole::Poly);
    let (sn, dn) = draw_sd(cell, tech, g, x, y_n, w_n, false);
    let (sp, dp) = draw_sd_off(cell, tech, g, x, y_p, w_p, true, p_s_dy, p_d_dy);
    let gx0 = x + g.cont_enc_active + g.cont + g.gate_to_cont;
    let gxc = gx0 + g.gate_l / 2;
    cell.add(Rect::new(poly, gx0, y_n - g.gate_ext, gx0 + g.gate_l, y_p + w_p + g.gate_ext));
    if pad_dx != 0 {
        let px = gxc + pad_dx;
        let (jx0, jx1) = if pad_dx < 0 { (px, gxc + g.gate_l / 2) } else { (gx0, px) };
        cell.add(Rect::new(poly, jx0, pad_y - g.gate_l / 2, jx1, pad_y + g.gate_l / 2));
    }
    let gstub = gate_pad(cell, tech, g, gxc + pad_dx, pad_y);
    (
        MosStubs { s: sn, g: gstub, d: dn, w_nm: w_n },
        MosStubs { s: sp, g: gstub, d: dp, w_nm: w_p },
    )
}

// ---------------------------------------------------------------------------
// Routing helpers
// ---------------------------------------------------------------------------

/// Vertical metal1 wire from (x, y_a) to (x, y_b).
fn vwire(cell: &mut Cell, tech: &Tech, x: i64, y_a: i64, y_b: i64) {
    let m1 = tech.layer(LayerRole::Metal1);
    let w = tech.rules.layer(LayerRole::Metal1).min_width_nm;
    let (lo, hi) = if y_a <= y_b { (y_a, y_b) } else { (y_b, y_a) };
    cell.add(Rect::new(m1, x - w / 2, lo - w / 2, x + w / 2, hi + w / 2));
}

/// via1 with m1/m2 landing pads at (x, y).
fn via1_at(cell: &mut Cell, tech: &Tech, x: i64, y: i64) {
    let v1 = tech.layer(LayerRole::Via1);
    let m1 = tech.layer(LayerRole::Metal1);
    let m2 = tech.layer(LayerRole::Metal2);
    let vw = tech.rules.layer(LayerRole::Via1).min_width_nm;
    cell.add(Rect::new(v1, x - vw / 2, y - vw / 2, x + vw / 2, y + vw / 2));
    cell.add(Rect::new(m1, x - vw / 2 - 10, y - vw / 2 - 10, x + vw / 2 + 10, y + vw / 2 + 10));
    cell.add(Rect::new(m2, x - vw / 2 - 10, y - vw / 2 - 10, x + vw / 2 + 10, y + vw / 2 + 10));
}

/// Tie terminal stubs together on a horizontal metal2 track at `y`.
fn net_track(cell: &mut Cell, tech: &Tech, g: &Geom, y: i64, stubs: &[Stub]) {
    let m2 = tech.layer(LayerRole::Metal2);
    let mut xs: Vec<i64> = Vec::new();
    for s in stubs {
        vwire(cell, tech, s.x, s.y, y);
        via1_at(cell, tech, s.x, y);
        xs.push(s.x);
    }
    let (lo, hi) = (*xs.iter().min().unwrap(), *xs.iter().max().unwrap());
    cell.add(Rect::new(m2, lo - 40, y - g.m2_w / 2, hi + 40, y + g.m2_w / 2));
}

/// Connect a terminal stub to a full-height metal2 bitline at `blx`
/// with a horizontal m1 jog + via1.
fn bitline_tap(cell: &mut Cell, tech: &Tech, g: &Geom, blx: i64, stub: Stub) {
    let m1 = tech.layer(LayerRole::Metal1);
    let y = stub.y;
    let (lo, hi) = if stub.x <= blx { (stub.x, blx) } else { (blx, stub.x) };
    cell.add(Rect::new(m1, lo - g.m1_w / 2, y - g.m1_w / 2, hi + g.m1_w / 2, y + g.m1_w / 2));
    via1_at(cell, tech, blx, y);
}

/// Wordline drop: connect a gate-pad/terminal stub up to a metal3 strap
/// (m1 -> via1 -> m2 stub -> via2 -> m3).
fn wl_m3_drop(cell: &mut Cell, tech: &Tech, strap: Rect, stub: Stub) {
    let m2 = tech.layer(LayerRole::Metal2);
    let v2 = tech.layer(LayerRole::Via2);
    let yc = (strap.y0 + strap.y1) / 2;
    vwire(cell, tech, stub.x, stub.y, yc);
    via1_at(cell, tech, stub.x, yc);
    cell.add(Rect::new(m2, stub.x - 40, yc - 40, stub.x + 40, yc + 40));
    let vw = tech.rules.layer(LayerRole::Via2).min_width_nm;
    cell.add(Rect::new(v2, stub.x - vw / 2, yc - vw / 2, stub.x + vw / 2, yc + vw / 2));
}

// ---------------------------------------------------------------------------
// Bitcells
// ---------------------------------------------------------------------------

/// 6T SRAM bitcell (logic design rules, Fig. 2(c)/3(c)).
/// Boundary 1520 x 660 nm -> 1.003 um^2.  Ports: bl, blb, wl, vdd, gnd.
pub fn sram6t(tech: &Tech) -> LeafCell {
    let g = Geom::of(tech);
    let mut cell = Cell::new("sram6t");
    let mut ckt = Circuit::new("sram6t", &["bl", "blb", "wl", "vdd", "gnd"]);
    let (bw, bh) = (1520i64, 660i64);
    let m2 = tech.layer(LayerRole::Metal2);
    let m1 = tech.layer(LayerRole::Metal1);
    let m3 = tech.layer(LayerRole::Metal3);

    let (yn, wn) = (160i64, 120i64); // nmos row
    let (yp, wp) = (490i64, 140i64); // pmos row
    let (q_y, qb_y) = (340i64, 430i64);
    let wl_strap = Rect::new(m3, 0, 480, bw, 540);

    // rails
    cell.pin("gnd", Rect::new(m1, 0, 0, bw, g.rail_w));
    cell.pin("vdd", Rect::new(m1, 0, bh - g.rail_w, bw, bh));
    // bitlines at the edges
    cell.pin("bl", Rect::new(m2, 40, 0, 40 + g.m2_w, bh));
    cell.pin("blb", Rect::new(m2, bw - 40 - g.m2_w, 0, bw - 40, bh));

    // access transistors (single, gate pad toward the cell edge)
    let axl = draw_mos(&mut cell, tech, &g, 40, yn, wn, false, GateAt { pad_y: 340, pad_dx: 0 });
    let axr = draw_mos(&mut cell, tech, &g, 1180, yn, wn, false, GateAt { pad_y: 340, pad_dx: 0 });
    // cross-coupled pairs share poly columns; each pair's pad sits ON
    // the track of the net it receives (left pair <- qb, right <- q).
    // pmos sources slide UP so their pads merge with the vdd rail;
    // drains slide DOWN to stay clear of it.
    let (pdl, pul) = draw_pair_off(&mut cell, tech, &g, 420, yn, wn, yp, wp, qb_y, 0, 20, -20);
    let (pdr, pur) = draw_pair_off(&mut cell, tech, &g, 800, yn, wn, yp, wp, q_y, 0, 20, -20);

    let wl = wn as f64 / g.gate_l as f64;
    let wlp = wp as f64 / g.gate_l as f64;
    ckt.mos("axl", "q", "wl", "bl", "gnd", "si_nmos", wl);
    ckt.mos("pdl", "q", "qb", "gnd", "gnd", "si_nmos", wl);
    ckt.mos("pul", "q", "qb", "vdd", "vdd", "si_pmos", wlp);
    ckt.mos("pdr", "qb", "q", "gnd", "gnd", "si_nmos", wl);
    ckt.mos("pur", "qb", "q", "vdd", "vdd", "si_pmos", wlp);
    ckt.mos("axr", "qb", "wl", "blb", "gnd", "si_nmos", wl);

    // q: axl.d, pdl.d, pul.d and the right pair's gate pad
    net_track(&mut cell, tech, &g, q_y, &[axl.d, pdl.d, pul.d, pdr.g]);
    // qb: axr.s, pdr.d, pur.d and the left pair's gate pad
    net_track(&mut cell, tech, &g, qb_y, &[axr.s, pdr.d, pur.d, pdl.g]);
    // bitline taps
    bitline_tap(&mut cell, tech, &g, 40 + g.m2_w / 2, axl.s);
    bitline_tap(&mut cell, tech, &g, bw - 40 - g.m2_w / 2, axr.d);
    // rails
    vwire(&mut cell, tech, pdl.s.x, pdl.s.y, g.rail_w / 2);
    vwire(&mut cell, tech, pdr.s.x, pdr.s.y, g.rail_w / 2);
    vwire(&mut cell, tech, pul.s.x, pul.s.y, bh - g.rail_w / 2);
    vwire(&mut cell, tech, pur.s.x, pur.s.y, bh - g.rail_w / 2);
    // wordline on m3 with drops to both access gates
    cell.pin("wl", wl_strap);
    wl_m3_drop(&mut cell, tech, wl_strap, axl.g);
    wl_m3_drop(&mut cell, tech, wl_strap, axr.g);

    let b = tech.layer(LayerRole::Boundary);
    cell.add(Rect::new(b, 0, 0, bw, bh));
    LeafCell { layout: cell, circuit: ckt }
}

/// 2T Si-Si gain cell (Fig. 2(a)): NMOS write + PMOS read (default NP
/// flavor) or NMOS read (`nn_flavor`, the legacy active-low-RWL cell).
/// Boundary 1050 x 660 -> 69 % of the 6T cell.
/// Ports: wbl, wwl, rbl, rwl, gnd.
pub fn gc2t_sisi(tech: &Tech, nn_flavor: bool) -> LeafCell {
    let g = Geom::of(tech);
    let name = if nn_flavor { "gc2t_sisi_nn" } else { "gc2t_sisi" };
    let mut cell = Cell::new(name);
    let mut ckt = Circuit::new(name, &["wbl", "wwl", "rbl", "rwl", "gnd"]);
    let (bw, bh) = (1050i64, 660i64);
    let m1 = tech.layer(LayerRole::Metal1);
    let m2 = tech.layer(LayerRole::Metal2);
    let m3 = tech.layer(LayerRole::Metal3);

    let (yr, w_wr, w_rd) = (120i64, 100i64, 140i64);
    cell.pin("gnd", Rect::new(m1, 0, 0, bw, g.rail_w));
    cell.pin("wbl", Rect::new(m2, 20, 0, 20 + g.m2_w, bh));
    cell.pin("rbl", Rect::new(m2, bw - 80, 0, bw - 20, bh));

    let mw = draw_mos(&mut cell, tech, &g, 60, yr, w_wr, false, GateAt { pad_y: 340, pad_dx: -60 });
    let mr = draw_mos(&mut cell, tech, &g, 560, yr, w_rd, !nn_flavor, GateAt { pad_y: 340, pad_dx: 0 });
    let rd_card = if nn_flavor { "si_nmos" } else { "si_pmos" };
    ckt.mos("mw", "sn", "wwl", "wbl", "gnd", "si_nmos", w_wr as f64 / g.gate_l as f64);
    ckt.mos("mr", "rbl", "sn", "rwl", "gnd", rd_card, w_rd as f64 / g.gate_l as f64);

    // storage node: mw.d up to a track tying into mr's gate pad
    net_track(&mut cell, tech, &g, 340, &[mw.d, mr.g]);
    // bitline taps
    bitline_tap(&mut cell, tech, &g, 20 + g.m2_w / 2, mw.s);
    bitline_tap(&mut cell, tech, &g, bw - 50, mr.d);
    // wordlines on m3
    let wwl_strap = Rect::new(m3, 0, 440, bw, 500);
    let rwl_strap = Rect::new(m3, 0, 560, bw, 620);
    cell.pin("wwl", wwl_strap);
    cell.pin("rwl", rwl_strap);
    wl_m3_drop(&mut cell, tech, wwl_strap, mw.g);
    // rwl drives the read tx SOURCE (2T gain cell: selection by source)
    wl_m3_drop(&mut cell, tech, rwl_strap, mr.s);

    let b = tech.layer(LayerRole::Boundary);
    cell.add(Rect::new(b, 0, 0, bw, bh));
    LeafCell { layout: cell, circuit: ckt }
}

/// 2T OS-OS gain cell (Fig. 2(b)): both transistors in the BEOL between
/// M2 and M3; no FEOL silicon area (3D-stackable, paper §V-A/B).
/// Boundary 430 x 264 -> ~11 % of the 6T cell footprint.
/// Ports: wbl, wwl, rbl, rwl.
pub fn gc2t_osos(tech: &Tech) -> LeafCell {
    let mut cell = Cell::new("gc2t_osos");
    let mut ckt = Circuit::new("gc2t_osos", &["wbl", "wwl", "rbl", "rwl"]);
    let (bw, bh) = (430i64, 264i64);
    let ch = tech.layer(LayerRole::OsChannel);
    let gate = tech.layer(LayerRole::OsGate);
    let m2 = tech.layer(LayerRole::Metal2);
    let m3 = tech.layer(LayerRole::Metal3);
    let v2 = tech.layer(LayerRole::Via2);

    let l = 50i64;
    ckt.mos("mw", "sn", "wwl", "wbl", "wbl", "os_nmos", 50.0 / l as f64);
    ckt.mos("mr", "rbl", "sn", "rwl", "rwl", "os_nmos", 50.0 / l as f64);

    // write tx: channel row y 170..220, gate column x 200..250
    cell.add(Rect::new(ch, 110, 170, 340, 220));
    cell.add(Rect::new(gate, 200, 145, 250, 245));
    // read tx: channel row y 40..90 (shifted right), gate x 260..310
    cell.add(Rect::new(ch, 170, 40, 400, 90));
    cell.add(Rect::new(gate, 260, 15, 310, 115));

    // wbl: m2 column + jumper + via2 cut onto the write source region
    // (all array-internal via2 cuts stay below y=204 so the full-width
    // wwl m3 strap never shorts to them)
    cell.pin("wbl", Rect::new(m2, 0, 0, 60, bh));
    cell.add(Rect::new(m2, 0, 152, 165, 212)); // jumper + S pad
    cell.add(Rect::new(v2, 115, 172, 155, 202));
    // rbl: m2 column; read drain pad touches it directly
    cell.pin("rbl", Rect::new(m2, 370, 0, bw, bh));
    cell.add(Rect::new(m2, 345, 30, 405, 95)); // D pad (touches column)
    cell.add(Rect::new(v2, 355, 42, 395, 72)); // below the rwl strap
    // sn: write drain pad -> leg down -> read gate pad (via2 cuts)
    cell.add(Rect::new(m2, 280, 152, 340, 212)); // mw.d pad
    cell.add(Rect::new(v2, 290, 172, 330, 202));
    cell.add(Rect::new(m2, 265, 0, 325, 212)); // leg (under channels: no cut, no connect)
    cell.add(Rect::new(gate, 255, 0, 335, 40)); // osgate pad (clear of channel y>=40)
    cell.add(Rect::new(v2, 275, 10, 315, 40)); // gate cut (inside the leg)
    // wwl: write-gate pad -> m2 stub -> via2 -> m3 strap (top-left)
    let wwl_strap = Rect::new(m3, 0, 204, bw, bh);
    cell.pin("wwl", wwl_strap);
    cell.add(Rect::new(gate, 185, 221, 255, bh)); // clear of the channel (y<=220)
    cell.add(Rect::new(m2, 185, 155, 245, bh));
    cell.add(Rect::new(v2, 195, 222, 235, 252)); // strictly above the channel (y>220)
    // rwl: m3 strap between the rows (clear of the sn gate cuts) + a
    // read-source pad with separate m3-drop and channel-contact cuts
    let rwl_strap = Rect::new(m3, 0, 75, bw, 135);
    cell.pin("rwl", rwl_strap);
    cell.add(Rect::new(m2, 80, 0, 230, 132)); // mr.s pad + drop
    cell.add(Rect::new(v2, 90, 85, 130, 115)); // m3 -> m2
    cell.add(Rect::new(v2, 180, 45, 220, 75)); // m2 -> channel (S)

    let b = tech.layer(LayerRole::Boundary);
    cell.add(Rect::new(b, 0, 0, bw, bh));
    LeafCell { layout: cell, circuit: ckt }
}

// ---------------------------------------------------------------------------
// Periphery leaf cells (standard-cell style)
// ---------------------------------------------------------------------------

const YN: i64 = 150; // nmos row y
const YP: i64 = 550; // pmos row y
const T1: i64 = 380; // m2 net track 1
const T2: i64 = 480; // m2 net track 2
const T0: i64 = 280; // low m2 track (within the nmos row band)
const PAD_N: i64 = 430; // gate pad y for nmos-only columns
const PAD_P: i64 = 480; // gate pad y for pmos-only columns (mid zone)
const PAD_PH: i64 = 780; // gate pad y above the pmos row
const PAD_SH: i64 = 430; // shared-column pad y

/// Standard-cell frame: gnd rail bottom, vdd rail top.
struct Std {
    cell: Cell,
    ckt: Circuit,
    g: Geom,
    bw: i64,
    bh: i64,
}

impl Std {
    fn new(tech: &Tech, name: &str, ports: &[&str], bw: i64) -> Std {
        let g = Geom::of(tech);
        let bh = 900;
        let m1 = tech.layer(LayerRole::Metal1);
        let mut cell = Cell::new(name);
        cell.pin("gnd", Rect::new(m1, 0, 0, bw, g.rail_w));
        cell.pin("vdd", Rect::new(m1, 0, bh - g.rail_w, bw, bh));
        Std { cell, ckt: Circuit::new(name, ports), g, bw, bh }
    }

    fn pin_at(&mut self, name: &str, tech: &Tech, s: Stub) {
        let m1 = tech.layer(LayerRole::Metal1);
        self.cell.pins.push(Pin {
            name: name.into(),
            rect: Rect::new(m1, s.x - PAD / 2, s.y - PAD / 2, s.x + PAD / 2, s.y + PAD / 2),
        });
    }

    fn track_pin(&mut self, name: &str, _tech: &Tech, y: i64, x: i64) {
        let m2 = _tech.layer(LayerRole::Metal2);
        self.cell.pins.push(Pin {
            name: name.into(),
            rect: Rect::new(m2, x - 40, y - self.g.m2_w / 2, x + 40, y + self.g.m2_w / 2),
        });
    }

    fn rail(&mut self, tech: &Tech, s: Stub, top: bool) {
        let y = if top { self.bh - self.g.rail_w / 2 } else { self.g.rail_w / 2 };
        vwire(&mut self.cell, tech, s.x, s.y, y);
    }

    fn finish(mut self, tech: &Tech) -> LeafCell {
        let b = tech.layer(LayerRole::Boundary);
        self.cell.add(Rect::new(b, 0, 0, self.bw, self.bh));
        LeafCell { layout: self.cell, circuit: self.ckt }
    }
}

/// Inverter with drive strength `drive` (geometry capped at the row
/// height; electrical W/L always scales with the drive).
pub fn inverter(tech: &Tech, drive: f64) -> LeafCell {
    let name = format!("inv_x{}", drive as i64);
    let mut s = Std::new(tech, &name, &["a", "y", "vdd", "gnd"], 560);
    let g = s.g;
    // geometry (and therefore the netlist W/L -- they must agree for
    // LVS) caps at the row height; larger drives would use fingers
    let wn = (110.0 * drive).min(220.0) as i64;
    let wp = (180.0 * drive).min(300.0) as i64;
    let wl_n = wn as f64 / g.gate_l as f64;
    let wl_p = wp as f64 / g.gate_l as f64;
    let (mn, mp) = draw_pair(&mut s.cell, tech, &g, 120, YN, wn, YP, wp, PAD_SH, 0);
    s.ckt.mos("mn", "y", "a", "gnd", "gnd", "si_nmos", wl_n);
    s.ckt.mos("mp", "y", "a", "vdd", "vdd", "si_pmos", wl_p);
    net_track(&mut s.cell, tech, &g, T1, &[mn.d, mp.d]);
    s.pin_at("a", tech, mn.g);
    s.track_pin("y", tech, T1, mn.d.x);
    s.rail(tech, mn.s, false);
    s.rail(tech, mp.s, true);
    s.finish(tech)
}

/// 2-input NAND.
pub fn nand2(tech: &Tech) -> LeafCell {
    let mut s = Std::new(tech, "nand2", &["a", "b", "y", "vdd", "gnd"], 1000);
    let g = s.g;
    let (wn, wp) = (160i64, 180i64);
    let (mna, mpa) = draw_pair(&mut s.cell, tech, &g, 120, YN, wn, YP, wp, PAD_SH, 0);
    let (mnb, mpb) = draw_pair(&mut s.cell, tech, &g, 520, YN, wn, YP, wp, PAD_SH, 0);
    let wl = wn as f64 / g.gate_l as f64;
    let wlp = wp as f64 / g.gate_l as f64;
    s.ckt.mos("mna", "y", "a", "mid", "gnd", "si_nmos", wl);
    s.ckt.mos("mnb", "mid", "b", "gnd", "gnd", "si_nmos", wl);
    s.ckt.mos("mpa", "y", "a", "vdd", "vdd", "si_pmos", wlp);
    s.ckt.mos("mpb", "y", "b", "vdd", "vdd", "si_pmos", wlp);
    net_track(&mut s.cell, tech, &g, T1, &[mna.d, mpa.d, mpb.d]); // y
    net_track(&mut s.cell, tech, &g, T0, &[mna.s, mnb.d]); // mid
    s.pin_at("a", tech, mna.g);
    s.pin_at("b", tech, mnb.g);
    s.track_pin("y", tech, T1, mpb.d.x);
    s.rail(tech, mnb.s, false);
    s.rail(tech, mpa.s, true);
    s.rail(tech, mpb.s, true);
    s.finish(tech)
}

/// Single-ended sense amplifier (diff pair vs VREF; paper §V-A).
pub fn sense_amp(tech: &Tech) -> LeafCell {
    let mut s = Std::new(tech, "sense_amp", &["rbl", "vref", "sae", "out", "vdd", "gnd"], 1500);
    let g = s.g;
    let w = 160i64;
    let wl = w as f64 / g.gate_l as f64;
    let min1 = draw_mos(&mut s.cell, tech, &g, 100, YN, w, false, GateAt { pad_y: PAD_N, pad_dx: 0 });
    let min2 = draw_mos(&mut s.cell, tech, &g, 500, YN, w, false, GateAt { pad_y: PAD_N, pad_dx: 0 });
    let mtail = draw_mos(&mut s.cell, tech, &g, 900, YN, w, false, GateAt { pad_y: PAD_N, pad_dx: 0 });
    // pmos loads staggered so their pads clear the nmos pads in x
    let mld1 = draw_mos(&mut s.cell, tech, &g, 250, YP, w, true, GateAt { pad_y: PAD_P, pad_dx: 0 });
    let mld2 = draw_mos(&mut s.cell, tech, &g, 700, YP, w, true, GateAt { pad_y: PAD_P, pad_dx: 0 });
    s.ckt.mos("min1", "outb", "rbl", "tail", "gnd", "si_nmos", wl);
    s.ckt.mos("min2", "out", "vref", "tail", "gnd", "si_nmos", wl);
    s.ckt.mos("mtail", "tail", "sae", "gnd", "gnd", "si_nmos", wl);
    s.ckt.mos("mld1", "outb", "outb", "vdd", "vdd", "si_pmos", wl);
    s.ckt.mos("mld2", "out", "outb", "vdd", "vdd", "si_pmos", wl);
    net_track(&mut s.cell, tech, &g, T1, &[min1.d, mld1.d, mld1.g, mld2.g]); // outb
    net_track(&mut s.cell, tech, &g, T2, &[min2.d, mld2.d]); // out
    net_track(&mut s.cell, tech, &g, T0, &[min1.s, min2.s, mtail.d]); // tail
    s.pin_at("rbl", tech, min1.g);
    s.pin_at("vref", tech, min2.g);
    s.pin_at("sae", tech, mtail.g);
    s.track_pin("out", tech, T2, mld2.d.x);
    s.rail(tech, mtail.s, false);
    s.rail(tech, mld1.s, true);
    s.rail(tech, mld2.s, true);
    s.finish(tech)
}

/// Single-ended write driver (BLb half removed; paper §V-A).
pub fn write_driver(tech: &Tech) -> LeafCell {
    let mut s = Std::new(tech, "write_driver", &["din_b", "en", "wbl", "vdd", "gnd"], 1000);
    let g = s.g;
    let (wn, wp) = (220i64, 300i64);
    let (mn, mp) = draw_pair(&mut s.cell, tech, &g, 120, YN, wn, YP, wp, PAD_SH, 0);
    let men = draw_mos(&mut s.cell, tech, &g, 520, YN, wn, false, GateAt { pad_y: PAD_N, pad_dx: 60 });
    s.ckt.mos("mp", "wbl", "din_b", "vdd", "vdd", "si_pmos", wp as f64 / g.gate_l as f64);
    s.ckt.mos("mn", "wbl", "din_b", "nst", "gnd", "si_nmos", wn as f64 / g.gate_l as f64);
    s.ckt.mos("men", "nst", "en", "gnd", "gnd", "si_nmos", wn as f64 / g.gate_l as f64);
    net_track(&mut s.cell, tech, &g, T1, &[mn.d, mp.d]); // wbl
    net_track(&mut s.cell, tech, &g, T0, &[mn.s, men.d]); // nst
    s.pin_at("din_b", tech, mn.g);
    s.pin_at("en", tech, men.g);
    s.track_pin("wbl", tech, T1, mn.d.x);
    s.rail(tech, men.s, false);
    s.rail(tech, mp.s, true);
    s.finish(tech)
}

/// RBL precharge (PMOS, active-low en_b): SRAM and OS-OS read ports.
pub fn precharge(tech: &Tech) -> LeafCell {
    let mut s = Std::new(tech, "precharge", &["en_b", "bl", "vdd", "gnd"], 560);
    let g = s.g;
    let wp = 240;
    let mp = draw_mos(&mut s.cell, tech, &g, 120, YP, wp, true, GateAt { pad_y: PAD_P, pad_dx: 0 });
    s.ckt.mos("mp", "bl", "en_b", "vdd", "vdd", "si_pmos", wp as f64 / g.gate_l as f64);
    net_track(&mut s.cell, tech, &g, T1, &[mp.d]);
    s.pin_at("en_b", tech, mp.g);
    s.track_pin("bl", tech, T1, mp.d.x);
    s.rail(tech, mp.s, true);
    s.finish(tech)
}

/// RBL predischarge (NMOS, active-high en): the new module the paper
/// adds for the Si-Si GCRAM read port (§V-A).
pub fn predischarge(tech: &Tech) -> LeafCell {
    let mut s = Std::new(tech, "predischarge", &["en", "bl", "vdd", "gnd"], 560);
    let g = s.g;
    let wn = 240;
    let mn = draw_mos(&mut s.cell, tech, &g, 120, YN, wn, false, GateAt { pad_y: PAD_N, pad_dx: 0 });
    s.ckt.mos("mn", "bl", "en", "gnd", "gnd", "si_nmos", wn as f64 / g.gate_l as f64);
    net_track(&mut s.cell, tech, &g, T2, &[mn.d]);
    s.pin_at("en", tech, mn.g);
    s.track_pin("bl", tech, T2, mn.d.x);
    s.rail(tech, mn.s, false);
    s.finish(tech)
}

/// WWL level shifter (cross-coupled PMOS on the boosted vpp rail;
/// Fig. 7(a) green points / Fig. 8(c)).
pub fn level_shifter(tech: &Tech) -> LeafCell {
    let mut s = Std::new(tech, "level_shifter", &["in", "in_b", "out", "vpp", "gnd"], 1100);
    let g = s.g;
    let w = 160i64;
    let wl = w as f64 / g.gate_l as f64;
    let mn1 = draw_mos(&mut s.cell, tech, &g, 120, YN, w, false, GateAt { pad_y: PAD_N, pad_dx: -60 });
    let mn2 = draw_mos(&mut s.cell, tech, &g, 520, YN, w, false, GateAt { pad_y: PAD_N, pad_dx: -60 });
    let mp1 = draw_mos(&mut s.cell, tech, &g, 250, YP, w, true, GateAt { pad_y: PAD_P, pad_dx: 0 });
    let mp2 = draw_mos(&mut s.cell, tech, &g, 720, YP, w, true, GateAt { pad_y: PAD_P, pad_dx: 0 });
    s.ckt.mos("mn1", "outb", "in", "gnd", "gnd", "si_nmos", wl);
    s.ckt.mos("mn2", "out", "in_b", "gnd", "gnd", "si_nmos", wl);
    s.ckt.mos("mp1", "outb", "out", "vpp", "vpp", "si_pmos", wl);
    s.ckt.mos("mp2", "out", "outb", "vpp", "vpp", "si_pmos", wl);
    net_track(&mut s.cell, tech, &g, T1, &[mn1.d, mp1.d, mp2.g]); // outb
    net_track(&mut s.cell, tech, &g, T2, &[mn2.d, mp2.d, mp1.g]); // out
    s.pin_at("in", tech, mn1.g);
    s.pin_at("in_b", tech, mn2.g);
    s.track_pin("out", tech, T2, mp2.d.x);
    s.rail(tech, mn1.s, false);
    s.rail(tech, mn2.s, false);
    s.rail(tech, mp1.s, true);
    s.rail(tech, mp2.s, true);
    for p in &mut s.cell.pins {
        if p.name == "vdd" {
            p.name = "vpp".into(); // boosted rail
        }
    }
    s.finish(tech)
}

/// Column-mux pass gate.
pub fn column_mux(tech: &Tech) -> LeafCell {
    let mut s = Std::new(tech, "column_mux", &["sel", "bl_in", "bl_out", "vdd", "gnd"], 560);
    let g = s.g;
    let wn = 220;
    let mn = draw_mos(&mut s.cell, tech, &g, 120, YN, wn, false, GateAt { pad_y: PAD_N, pad_dx: 0 });
    s.ckt.mos("mn", "bl_out", "sel", "bl_in", "gnd", "si_nmos", wn as f64 / g.gate_l as f64);
    net_track(&mut s.cell, tech, &g, T2, &[mn.d]);
    net_track(&mut s.cell, tech, &g, T0, &[mn.s]);
    s.pin_at("sel", tech, mn.g);
    s.track_pin("bl_out", tech, T2, mn.d.x);
    s.track_pin("bl_in", tech, T0, mn.s.x);
    s.finish(tech)
}

/// Transmission gate (nmos + pmos pass pair) — building block of the
/// composed Data_DFF (see [`super::compose`]).
pub fn tgate(tech: &Tech) -> LeafCell {
    let mut s = Std::new(tech, "tgate", &["a", "b", "cn", "cp", "vdd", "gnd"], 800);
    let g = s.g;
    let (wn, wp) = (120i64, 180i64);
    let mn = draw_mos(&mut s.cell, tech, &g, 120, YN, wn, false, GateAt { pad_y: PAD_N, pad_dx: 0 });
    let mp = draw_mos(&mut s.cell, tech, &g, 420, YP, wp, true, GateAt { pad_y: PAD_PH, pad_dx: 0 });
    s.ckt.mos("mn", "b", "cn", "a", "gnd", "si_nmos", wn as f64 / g.gate_l as f64);
    s.ckt.mos("mp", "b", "cp", "a", "vdd", "si_pmos", wp as f64 / g.gate_l as f64);
    net_track(&mut s.cell, tech, &g, T0, &[mn.s, mp.s]); // a
    net_track(&mut s.cell, tech, &g, T2, &[mn.d, mp.d]); // b
    s.pin_at("cn", tech, mn.g);
    s.pin_at("cp", tech, mp.g);
    s.track_pin("a", tech, T0, mn.s.x);
    s.track_pin("b", tech, T2, mn.d.x);
    s.finish(tech)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tech::sg40;

    fn area_um2(lc: &LeafCell, tech: &Tech) -> f64 {
        let b = tech.layer(LayerRole::Boundary);
        let r = lc.layout.boundary(b).expect("boundary");
        (r.w() as f64 * r.h() as f64) * 1e-6
    }

    #[test]
    fn fig3_cell_area_ratios() {
        let t = sg40();
        let sram = area_um2(&sram6t(&t), &t);
        let sisi = area_um2(&gc2t_sisi(&t, false), &t);
        let osos = area_um2(&gc2t_osos(&t), &t);
        let r_sisi = sisi / sram;
        let r_osos = osos / sram;
        // paper Fig. 3: 69 % and 11 %
        assert!((r_sisi - 0.69).abs() < 0.03, "Si-Si ratio {r_sisi}");
        assert!((r_osos - 0.11).abs() < 0.02, "OS-OS ratio {r_osos}");
    }

    #[test]
    fn bitcells_have_edge_bitlines_for_abutment() {
        let t = sg40();
        let b = t.layer(LayerRole::Boundary);
        for lc in [gc2t_sisi(&t, false), sram6t(&t), gc2t_osos(&t)] {
            let bnd = lc.layout.boundary(b).unwrap();
            for pin in &lc.layout.pins {
                if pin.name.contains("bl") {
                    assert_eq!(pin.rect.y0, 0, "{} {} bitline to cell bottom", lc.layout.name, pin.name);
                    assert_eq!(pin.rect.y1, bnd.y1, "{} {} bitline to cell top", lc.layout.name, pin.name);
                }
                if pin.name.ends_with("wl") {
                    assert_eq!(pin.rect.x0, 0, "{} {} wordline to left edge", lc.layout.name, pin.name);
                }
            }
        }
    }

    #[test]
    fn device_counts_match_schematics() {
        let t = sg40();
        assert_eq!(sram6t(&t).circuit.mos_count(), 6);
        assert_eq!(gc2t_sisi(&t, false).circuit.mos_count(), 2);
        assert_eq!(gc2t_osos(&t).circuit.mos_count(), 2);
        assert_eq!(sense_amp(&t).circuit.mos_count(), 5);
        assert_eq!(nand2(&t).circuit.mos_count(), 4);
        assert_eq!(level_shifter(&t).circuit.mos_count(), 4);
        assert_eq!(tgate(&t).circuit.mos_count(), 2);
    }

    #[test]
    fn os_cell_uses_no_feol_layers() {
        let t = sg40();
        let lc = gc2t_osos(&t);
        let feol: Vec<usize> = [LayerRole::Active, LayerRole::Poly, LayerRole::Nwell]
            .iter()
            .map(|r| t.layer(*r))
            .collect();
        for r in &lc.layout.rects {
            assert!(!feol.contains(&r.layer), "OS cell must be BEOL-only: {r:?}");
        }
    }

    #[test]
    fn inverter_scales_with_drive() {
        let t = sg40();
        let x1 = inverter(&t, 1.0);
        let x2 = inverter(&t, 2.0);
        let wl = |lc: &LeafCell| match &lc.circuit.devices[0] {
            crate::netlist::Device::Mos { w_over_l, .. } => *w_over_l,
            _ => panic!(),
        };
        assert!(wl(&x2) > 1.8 * wl(&x1));
    }

    #[test]
    fn all_cells_have_boundaries_and_port_pins() {
        let t = sg40();
        let b = t.layer(LayerRole::Boundary);
        for lc in [
            sram6t(&t),
            gc2t_sisi(&t, false),
            gc2t_sisi(&t, true),
            gc2t_osos(&t),
            inverter(&t, 1.0),
            nand2(&t),
            sense_amp(&t),
            write_driver(&t),
            precharge(&t),
            predischarge(&t),
            level_shifter(&t),
            column_mux(&t),
            tgate(&t),
        ] {
            assert!(lc.layout.boundary(b).is_some(), "{}", lc.layout.name);
            assert!(!lc.layout.pins.is_empty(), "{}", lc.layout.name);
            for port in &lc.circuit.ports {
                // bitcell 'gnd' bulk and similar rails always have pins
                let has = lc.layout.pins.iter().any(|p| &p.name == port);
                assert!(has, "{} missing pin {port}", lc.layout.name);
            }
        }
    }
}
