//! Layout geometry kernel: integer-nm Manhattan rectangles, hierarchical
//! cells with oriented instances, bounding boxes, flattening — plus the
//! cell generators ([`cells`]), the bank floorplanner ([`bank`]) and the
//! GDSII writer ([`gds`]).
//!
//! Conventions (relied on by the extractor in [`crate::lvs`]):
//! * transistors are drawn with **horizontal active strips crossed by
//!   vertical gates** (poly or osgate);
//! * all geometry is on a 5 nm grid;
//! * every cell carries a `Boundary` rect defining its abutment box.

pub mod bank;
pub mod cells;
pub mod compose;
pub mod gds;

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

/// Axis-aligned rectangle on a layer (coordinates in nm, `x0 < x1`,
/// `y0 < y1`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Rect {
    pub layer: usize,
    pub x0: i64,
    pub y0: i64,
    pub x1: i64,
    pub y1: i64,
}

impl Rect {
    pub fn new(layer: usize, x0: i64, y0: i64, x1: i64, y1: i64) -> Rect {
        debug_assert!(x0 <= x1 && y0 <= y1, "degenerate rect");
        Rect { layer, x0, y0, x1, y1 }
    }

    pub fn w(&self) -> i64 {
        self.x1 - self.x0
    }

    pub fn h(&self) -> i64 {
        self.y1 - self.y0
    }

    pub fn area_nm2(&self) -> i64 {
        self.w() * self.h()
    }

    /// Closed-interval overlap test on the same layer (abutting rects
    /// with a shared edge count as connected).
    pub fn touches(&self, o: &Rect) -> bool {
        self.layer == o.layer
            && self.x0 <= o.x1
            && o.x0 <= self.x1
            && self.y0 <= o.y1
            && o.y0 <= self.y1
    }

    /// Strict interior intersection across any layers.
    pub fn overlaps(&self, o: &Rect) -> bool {
        self.x0 < o.x1 && o.x0 < self.x1 && self.y0 < o.y1 && o.y0 < self.y1
    }

    pub fn intersection(&self, o: &Rect) -> Option<Rect> {
        let x0 = self.x0.max(o.x0);
        let y0 = self.y0.max(o.y0);
        let x1 = self.x1.min(o.x1);
        let y1 = self.y1.min(o.y1);
        if x0 < x1 && y0 < y1 {
            Some(Rect { layer: self.layer, x0, y0, x1, y1 })
        } else {
            None
        }
    }

    /// Does `self` contain `o` with at least `margin` on every side?
    pub fn encloses(&self, o: &Rect, margin: i64) -> bool {
        self.x0 + margin <= o.x0
            && self.y0 + margin <= o.y0
            && self.x1 - margin >= o.x1
            && self.y1 - margin >= o.y1
    }

    pub fn translated(&self, dx: i64, dy: i64) -> Rect {
        Rect { layer: self.layer, x0: self.x0 + dx, y0: self.y0 + dy, x1: self.x1 + dx, y1: self.y1 + dy }
    }

    pub fn union_bbox(&self, o: &Rect) -> Rect {
        Rect {
            layer: self.layer,
            x0: self.x0.min(o.x0),
            y0: self.y0.min(o.y0),
            x1: self.x1.max(o.x1),
            y1: self.y1.max(o.y1),
        }
    }
}

/// Placement orientation (the subset memory tiling needs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Orient {
    #[default]
    R0,
    /// Mirror about the x-axis (flip y) — row tiling of bitcells.
    Mx,
    /// Mirror about the y-axis (flip x).
    My,
    R180,
}

impl Orient {
    /// Dense index (memo-table slot).
    pub fn idx(&self) -> usize {
        match self {
            Orient::R0 => 0,
            Orient::Mx => 1,
            Orient::My => 2,
            Orient::R180 => 3,
        }
    }

    /// Apply to a rect, then translate by (dx, dy).
    pub fn apply(&self, r: &Rect, dx: i64, dy: i64) -> Rect {
        let (x0, y0, x1, y1) = match self {
            Orient::R0 => (r.x0, r.y0, r.x1, r.y1),
            Orient::Mx => (r.x0, -r.y1, r.x1, -r.y0),
            Orient::My => (-r.x1, r.y0, -r.x0, r.y1),
            Orient::R180 => (-r.x1, -r.y1, -r.x0, -r.y0),
        };
        Rect { layer: r.layer, x0: x0 + dx, y0: y0 + dy, x1: x1 + dx, y1: y1 + dy }
    }
}

/// Named pin shape (net label attached to a rect).
#[derive(Debug, Clone, PartialEq)]
pub struct Pin {
    pub name: String,
    pub rect: Rect,
}

/// Placed child cell.
#[derive(Debug, Clone)]
pub struct Instance {
    pub name: String,
    pub cell: String,
    pub dx: i64,
    pub dy: i64,
    pub orient: Orient,
}

/// A layout cell.
#[derive(Debug, Clone, Default)]
pub struct Cell {
    pub name: String,
    pub rects: Vec<Rect>,
    pub pins: Vec<Pin>,
    pub insts: Vec<Instance>,
}

impl Cell {
    pub fn new(name: impl Into<String>) -> Cell {
        Cell { name: name.into(), ..Default::default() }
    }

    pub fn add(&mut self, r: Rect) {
        self.rects.push(r);
    }

    pub fn pin(&mut self, name: impl Into<String>, r: Rect) {
        let name = name.into();
        self.rects.push(r);
        self.pins.push(Pin { name, rect: r });
    }

    pub fn place(&mut self, name: impl Into<String>, cell: &str, dx: i64, dy: i64, orient: Orient) {
        self.insts.push(Instance { name: name.into(), cell: cell.into(), dx, dy, orient });
    }

    /// Geometric bbox over local rects only (no instances).
    pub fn local_bbox(&self) -> Option<Rect> {
        let mut it = self.rects.iter();
        let first = *it.next()?;
        Some(it.fold(first, |a, b| a.union_bbox(b)))
    }

    /// Boundary rect if drawn, for abutment-pitch math.
    pub fn boundary(&self, boundary_layer: usize) -> Option<Rect> {
        self.rects.iter().copied().find(|r| r.layer == boundary_layer)
    }
}

/// A cell library (shared flat namespace, like one GDS file).
#[derive(Debug, Clone, Default)]
pub struct Library {
    pub cells: BTreeMap<String, Cell>,
}

impl Library {
    pub fn add(&mut self, c: Cell) {
        self.cells.insert(c.name.clone(), c);
    }

    pub fn get(&self, name: &str) -> crate::Result<&Cell> {
        self.cells
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("layout cell '{name}' not found"))
    }

    /// Flatten a cell to a rect soup (pins lost; DRC input).
    ///
    /// Memoized: the flattened rect list of every `(cell, orient)` pair
    /// is computed once and instances are emitted by translating the
    /// cached list, instead of re-walking the hierarchy per instance.
    /// A 128x128 bank references the identical bitcell ~16k times; the
    /// old recursive walk re-oriented every rect of every instance.
    pub fn flatten(&self, name: &str) -> crate::Result<Vec<Rect>> {
        let mut cache = FlattenCache::default();
        let shared = self.flat_cell(name, Orient::R0, &mut cache, 0)?;
        // the private cache holds the only other Arc; dropping it lets
        // the top-level list be returned without an O(n) copy
        drop(cache);
        Ok(Arc::try_unwrap(shared).unwrap_or_else(|arc| arc.as_ref().clone()))
    }

    /// [`Self::flatten`] with a caller-owned memo so repeated flattens
    /// (hierarchical DRC, sweeps, benches) share per-cell work.
    pub fn flatten_cached(&self, name: &str, cache: &mut FlattenCache) -> crate::Result<Vec<Rect>> {
        Ok(self.flat_cell(name, Orient::R0, cache, 0)?.as_ref().clone())
    }

    /// Memoized flattened rect list of `name` under `orient`, at the
    /// cell's local origin (shared, do not mutate).
    pub fn flatten_oriented(
        &self,
        name: &str,
        orient: Orient,
        cache: &mut FlattenCache,
    ) -> crate::Result<Arc<Vec<Rect>>> {
        self.flat_cell(name, orient, cache, 0)
    }

    fn flat_cell(
        &self,
        name: &str,
        orient: Orient,
        cache: &mut FlattenCache,
        depth: usize,
    ) -> crate::Result<Arc<Vec<Rect>>> {
        anyhow::ensure!(depth <= 32, "layout hierarchy too deep (cycle?)");
        if let Some(hit) = cache.get(name, orient) {
            return Ok(hit);
        }
        let c = self.get(name)?;
        let mut out: Vec<Rect> = Vec::with_capacity(c.rects.len());
        for r in &c.rects {
            out.push(orient.apply(r, 0, 0));
        }
        for i in &c.insts {
            // compose: child placed in parent frame, then parent's
            // transform applied.  For the Orient subset, composing is
            // applying parent's orient to the child's local offset and
            // multiplying orients.
            let (cdx, cdy) = match orient {
                Orient::R0 => (i.dx, i.dy),
                Orient::Mx => (i.dx, -i.dy),
                Orient::My => (-i.dx, i.dy),
                Orient::R180 => (-i.dx, -i.dy),
            };
            let comp = compose(orient, i.orient);
            let child = self.flat_cell(&i.cell, comp, cache, depth + 1)?;
            out.reserve(child.len());
            out.extend(child.iter().map(|r| r.translated(cdx, cdy)));
        }
        let shared = Arc::new(out);
        cache.put(name, orient, shared.clone());
        Ok(shared)
    }

    /// Reference flatten: the plain recursive walk the memoized path
    /// must reproduce exactly (kept for the equivalence tests).
    #[cfg(test)]
    fn flatten_reference(&self, name: &str) -> crate::Result<Vec<Rect>> {
        let mut out = Vec::new();
        self.flatten_into(name, 0, 0, Orient::R0, &mut out, 0)?;
        Ok(out)
    }

    #[cfg(test)]
    fn flatten_into(
        &self,
        name: &str,
        dx: i64,
        dy: i64,
        orient: Orient,
        out: &mut Vec<Rect>,
        depth: usize,
    ) -> crate::Result<()> {
        anyhow::ensure!(depth <= 32, "layout hierarchy too deep (cycle?)");
        let c = self.get(name)?;
        for r in &c.rects {
            out.push(orient.apply(r, dx, dy));
        }
        for i in &c.insts {
            let (cdx, cdy) = match orient {
                Orient::R0 => (i.dx, i.dy),
                Orient::Mx => (i.dx, -i.dy),
                Orient::My => (-i.dx, i.dy),
                Orient::R180 => (-i.dx, -i.dy),
            };
            let comp = compose(orient, i.orient);
            self.flatten_into(&i.cell, dx + cdx, dy + cdy, comp, out, depth + 1)?;
        }
        Ok(())
    }

    /// Flatten with pin propagation from the top cell only.
    pub fn flatten_with_pins(&self, name: &str) -> crate::Result<(Vec<Rect>, Vec<Pin>)> {
        let rects = self.flatten(name)?;
        let pins = self.get(name)?.pins.clone();
        Ok((rects, pins))
    }

    /// bbox of the flattened cell.
    pub fn bbox(&self, name: &str) -> crate::Result<Rect> {
        let rects = self.flatten(name)?;
        let mut it = rects.iter();
        let first = *it
            .next()
            .ok_or_else(|| anyhow::anyhow!("cell '{name}' is empty"))?;
        Ok(it.fold(first, |a, b| a.union_bbox(b)))
    }
}

/// Memo for [`Library::flatten`]: per-cell flattened rect lists under
/// each orientation, at the cell's local origin.  One `String` is
/// allocated per cell on first miss; lookups are by `&str`.
#[derive(Debug, Default)]
pub struct FlattenCache {
    map: HashMap<String, [Option<Arc<Vec<Rect>>>; 4]>,
}

impl FlattenCache {
    fn get(&self, name: &str, orient: Orient) -> Option<Arc<Vec<Rect>>> {
        self.map.get(name).and_then(|slots| slots[orient.idx()].clone())
    }

    fn put(&mut self, name: &str, orient: Orient, rects: Arc<Vec<Rect>>) {
        self.map.entry(name.to_string()).or_default()[orient.idx()] = Some(rects);
    }

    /// Number of memoized (cell, orient) entries.
    pub fn entries(&self) -> usize {
        self.map.values().map(|s| s.iter().flatten().count()).sum()
    }

    /// Drop all memoized lists (call after mutating the library).
    pub fn clear(&mut self) {
        self.map.clear();
    }
}

fn compose(outer: Orient, inner: Orient) -> Orient {
    use Orient::*;
    match (outer, inner) {
        (R0, x) => x,
        (x, R0) => x,
        (Mx, Mx) | (My, My) | (R180, R180) => R0,
        (Mx, My) | (My, Mx) => R180,
        (Mx, R180) | (R180, Mx) => My,
        (My, R180) | (R180, My) => Mx,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rect_basics() {
        let a = Rect::new(0, 0, 0, 100, 50);
        assert_eq!(a.area_nm2(), 5000);
        let b = Rect::new(0, 100, 0, 200, 50); // abuts a
        assert!(a.touches(&b));
        assert!(!a.overlaps(&b)); // zero-width intersection
        let c = Rect::new(0, 50, 10, 120, 40);
        assert_eq!(a.intersection(&c).unwrap(), Rect::new(0, 50, 10, 100, 40));
        assert!(a.encloses(&Rect::new(0, 10, 10, 90, 40), 10));
        assert!(!a.encloses(&Rect::new(0, 5, 10, 90, 40), 10));
    }

    #[test]
    fn orientation_transforms() {
        let r = Rect::new(1, 10, 20, 30, 40);
        assert_eq!(Orient::Mx.apply(&r, 0, 0), Rect::new(1, 10, -40, 30, -20));
        assert_eq!(Orient::My.apply(&r, 0, 0), Rect::new(1, -30, 20, -10, 40));
        assert_eq!(Orient::R180.apply(&r, 0, 0), Rect::new(1, -30, -40, -10, -20));
        // transform + translate
        assert_eq!(Orient::Mx.apply(&r, 5, 100), Rect::new(1, 15, 60, 35, 80));
    }

    #[test]
    fn orient_composition_is_group() {
        use Orient::*;
        // Mx . Mx = identity on a test rect through the library path
        let mut lib = Library::default();
        let mut leaf = Cell::new("leaf");
        leaf.add(Rect::new(0, 0, 0, 10, 20));
        lib.add(leaf);
        let mut mid = Cell::new("mid");
        mid.place("l", "leaf", 0, 0, Mx);
        lib.add(mid);
        let mut top = Cell::new("top");
        top.place("m", "mid", 0, 0, Mx);
        lib.add(top);
        let rects = lib.flatten("top").unwrap();
        assert_eq!(rects, vec![Rect::new(0, 0, 0, 10, 20)]);
    }

    #[test]
    fn flatten_tiles_rows() {
        let mut lib = Library::default();
        let mut cell = Cell::new("bit");
        cell.add(Rect::new(2, 0, 0, 100, 60));
        lib.add(cell);
        let mut arr = Cell::new("arr");
        for r in 0..4 {
            for c in 0..4 {
                let orient = if r % 2 == 0 { Orient::R0 } else { Orient::Mx };
                let dy = if r % 2 == 0 { r * 60 } else { r * 60 + 60 };
                arr.place(format!("b{r}_{c}"), "bit", c * 100, dy, orient);
            }
        }
        lib.add(arr);
        let rects = lib.flatten("arr").unwrap();
        assert_eq!(rects.len(), 16);
        let bbox = lib.bbox("arr").unwrap();
        assert_eq!((bbox.w(), bbox.h()), (400, 240));
    }

    #[test]
    fn missing_cell_is_error() {
        let lib = Library::default();
        assert!(lib.flatten("nope").is_err());
    }

    /// The memoized flatten must reproduce the reference recursive walk
    /// rect-for-rect (same multiset AND same order) for every generated
    /// cell under every orientation.
    #[test]
    fn memoized_flatten_matches_reference_walk_for_all_cells() {
        let t = crate::tech::sg40();
        let mut lib = Library::default();
        for lc in [
            cells::sram6t(&t),
            cells::gc2t_sisi(&t, false),
            cells::gc2t_sisi(&t, true),
            cells::gc2t_osos(&t),
            cells::inverter(&t, 1.0),
            cells::inverter(&t, 2.0),
            cells::nand2(&t),
            cells::sense_amp(&t),
            cells::write_driver(&t),
            cells::precharge(&t),
            cells::predischarge(&t),
            cells::level_shifter(&t),
            cells::column_mux(&t),
            cells::tgate(&t),
        ] {
            lib.add(lc.layout);
        }
        compose::dff(&mut lib, &t).unwrap();
        bank::tile_array(&mut lib, &t, "arr", "gc2t_sisi", 16, 16, 8, 400).unwrap();
        // a mixed-orientation top exercises every compose() branch
        let mut top = Cell::new("mixed");
        for (i, o) in [Orient::R0, Orient::Mx, Orient::My, Orient::R180].iter().enumerate() {
            top.place(format!("a{i}"), "arr", i as i64 * 20_000, 0, *o);
            top.place(format!("d{i}"), "dff", i as i64 * 20_000, -10_000, *o);
        }
        lib.add(top);

        let names: Vec<String> = lib.cells.keys().cloned().collect();
        let mut cache = FlattenCache::default();
        for name in &names {
            for orient in [Orient::R0, Orient::Mx, Orient::My, Orient::R180] {
                let mut reference = Vec::new();
                lib.flatten_into(name, 0, 0, orient, &mut reference, 0).unwrap();
                let memo = lib.flatten_oriented(name, orient, &mut cache).unwrap();
                assert_eq!(
                    memo.as_ref(),
                    &reference,
                    "flatten mismatch for cell '{name}' under {orient:?}"
                );
            }
        }
        // public single-shot path too
        for name in &names {
            assert_eq!(lib.flatten(name).unwrap(), lib.flatten_reference(name).unwrap());
        }
    }

    #[test]
    fn flatten_cache_is_reused_across_calls() {
        let t = crate::tech::sg40();
        let mut lib = Library::default();
        lib.add(cells::gc2t_sisi(&t, false).layout);
        bank::tile_array(&mut lib, &t, "arr", "gc2t_sisi", 32, 32, 16, 400).unwrap();
        let mut cache = FlattenCache::default();
        let a = lib.flatten_cached("arr", &mut cache).unwrap();
        // 1024 instances, but only (bitcell, R0) + (arr, R0) memo entries
        assert_eq!(cache.entries(), 2);
        let b = lib.flatten_cached("arr", &mut cache).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 32 * 32 * lib.get("gc2t_sisi").unwrap().rects.len() + 3 + 1);
    }
}
