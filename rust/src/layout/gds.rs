//! GDSII stream-format writer (and a minimal reader for round-trip
//! verification).  Database unit = 1 nm, user unit = 1 um.
//!
//! The paper's deliverable is "layout GDS files ready for tape out";
//! this module produces real GDSII binaries from a [`Library`], with
//! cells as structures and instances as SREFs (reflection encoded in
//! STRANS/ANGLE like every commercial reader expects).

use super::{Cell, Instance, Library, Orient, Rect};
use crate::tech::Tech;
use std::io::Write;

// GDS record types
const HEADER: u8 = 0x00;
const BGNLIB: u8 = 0x01;
const LIBNAME: u8 = 0x02;
const UNITS: u8 = 0x03;
const ENDLIB: u8 = 0x04;
const BGNSTR: u8 = 0x05;
const STRNAME: u8 = 0x06;
const ENDSTR: u8 = 0x07;
const BOUNDARY: u8 = 0x08;
const SREF: u8 = 0x0a;
const LAYER: u8 = 0x0d;
const DATATYPE: u8 = 0x0e;
const XY: u8 = 0x10;
const ENDEL: u8 = 0x11;
const SNAME: u8 = 0x12;
const STRANS: u8 = 0x1a;
const ANGLE: u8 = 0x1c;

// data types
const DT_NONE: u8 = 0x00;
const DT_I16: u8 = 0x02;
const DT_I32: u8 = 0x03;
const DT_F64: u8 = 0x05;
const DT_ASCII: u8 = 0x06;

fn rec(out: &mut Vec<u8>, rt: u8, dt: u8, payload: &[u8]) {
    let len = 4 + payload.len();
    assert!(len <= u16::MAX as usize);
    out.extend_from_slice(&(len as u16).to_be_bytes());
    out.push(rt);
    out.push(dt);
    out.extend_from_slice(payload);
}

fn rec_i16(out: &mut Vec<u8>, rt: u8, vals: &[i16]) {
    let mut p = Vec::with_capacity(vals.len() * 2);
    for v in vals {
        p.extend_from_slice(&v.to_be_bytes());
    }
    rec(out, rt, DT_I16, &p);
}

fn rec_i32(out: &mut Vec<u8>, rt: u8, vals: &[i32]) {
    let mut p = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        p.extend_from_slice(&v.to_be_bytes());
    }
    rec(out, rt, DT_I32, &p);
}

fn rec_str(out: &mut Vec<u8>, rt: u8, s: &str) {
    let mut p: Vec<u8> = s.bytes().collect();
    if p.len() % 2 == 1 {
        p.push(0);
    }
    rec(out, rt, DT_ASCII, &p);
}

/// GDSII 8-byte excess-64 floating point.
fn gds_f64(v: f64) -> [u8; 8] {
    if v == 0.0 {
        return [0; 8];
    }
    let neg = v < 0.0;
    let mut m = v.abs();
    let mut e: i32 = 64;
    while m >= 1.0 {
        m /= 16.0;
        e += 1;
    }
    while m < 1.0 / 16.0 {
        m *= 16.0;
        e -= 1;
    }
    let mant = (m * 2f64.powi(56)) as u64;
    let mut b = [0u8; 8];
    b[0] = (e as u8) | if neg { 0x80 } else { 0 };
    for i in 0..7 {
        b[1 + i] = ((mant >> (8 * (6 - i))) & 0xff) as u8;
    }
    b
}

fn rec_f64(out: &mut Vec<u8>, rt: u8, vals: &[f64]) {
    let mut p = Vec::with_capacity(vals.len() * 8);
    for v in vals {
        p.extend_from_slice(&gds_f64(*v));
    }
    rec(out, rt, DT_F64, &p);
}

const TIMESTAMP: [i16; 12] = [2026, 1, 1, 0, 0, 0, 2026, 1, 1, 0, 0, 0];

/// Serialize a library to GDSII bytes.  `tech` supplies gds layer
/// numbers (rect.layer indexes `tech.layers`).
pub fn write_bytes(lib: &Library, tech: &Tech, libname: &str) -> Vec<u8> {
    let mut out = Vec::new();
    rec_i16(&mut out, HEADER, &[600]);
    rec_i16(&mut out, BGNLIB, &TIMESTAMP);
    rec_str(&mut out, LIBNAME, libname);
    // db unit in user units (nm in um), db unit in meters
    rec_f64(&mut out, UNITS, &[1e-3, 1e-9]);
    for cell in lib.cells.values() {
        write_cell(&mut out, cell, tech);
    }
    rec(&mut out, ENDLIB, DT_NONE, &[]);
    out
}

fn write_cell(out: &mut Vec<u8>, cell: &Cell, tech: &Tech) {
    rec_i16(out, BGNSTR, &TIMESTAMP);
    rec_str(out, STRNAME, &cell.name);
    for r in &cell.rects {
        write_rect(out, r, tech);
    }
    for i in &cell.insts {
        write_sref(out, i);
    }
    rec(out, ENDSTR, DT_NONE, &[]);
}

fn write_rect(out: &mut Vec<u8>, r: &Rect, tech: &Tech) {
    let layer = &tech.layers[r.layer];
    rec(out, BOUNDARY, DT_NONE, &[]);
    rec_i16(out, LAYER, &[layer.gds]);
    rec_i16(out, DATATYPE, &[layer.datatype]);
    let (x0, y0, x1, y1) = (r.x0 as i32, r.y0 as i32, r.x1 as i32, r.y1 as i32);
    rec_i32(out, XY, &[x0, y0, x1, y0, x1, y1, x0, y1, x0, y0]);
    rec(out, ENDEL, DT_NONE, &[]);
}

fn write_sref(out: &mut Vec<u8>, i: &Instance) {
    rec(out, SREF, DT_NONE, &[]);
    rec_str(out, SNAME, &i.cell);
    // GDS expresses Mx/My/R180 via reflection bit + rotation angle
    let (reflect, angle) = match i.orient {
        Orient::R0 => (false, 0.0),
        Orient::R180 => (false, 180.0),
        Orient::Mx => (true, 0.0),    // mirror about x-axis
        Orient::My => (true, 180.0),  // mirror-x then rotate 180 == mirror-y
    };
    if reflect || angle != 0.0 {
        rec_i16(out, STRANS, &[if reflect { i16::MIN } else { 0 }]);
        if angle != 0.0 {
            rec_f64(out, ANGLE, &[angle]);
        }
    }
    rec_i32(out, XY, &[i.dx as i32, i.dy as i32]);
    rec(out, ENDEL, DT_NONE, &[]);
}

/// Write a library to a file.
pub fn write_file(
    lib: &Library,
    tech: &Tech,
    libname: &str,
    path: &std::path::Path,
) -> crate::Result<()> {
    let bytes = write_bytes(lib, tech, libname);
    let mut f = std::fs::File::create(path)?;
    f.write_all(&bytes)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Minimal reader (round-trip verification only)
// ---------------------------------------------------------------------------

/// Parsed GDS summary used by tests: structure names, boundary counts
/// per gds layer, sref targets.
#[derive(Debug, Default, PartialEq)]
pub struct GdsSummary {
    pub structures: Vec<String>,
    pub boundaries: Vec<(i16, i16, Vec<i32>)>,
    pub srefs: Vec<String>,
}

pub fn read_summary(bytes: &[u8]) -> crate::Result<GdsSummary> {
    let mut s = GdsSummary::default();
    let mut i = 0usize;
    let mut cur_layer: i16 = -1;
    let mut cur_dt: i16 = -1;
    let mut in_boundary = false;
    let mut in_sref = false;
    while i + 4 <= bytes.len() {
        let len = u16::from_be_bytes([bytes[i], bytes[i + 1]]) as usize;
        anyhow::ensure!(len >= 4 && i + len <= bytes.len(), "corrupt GDS record at {i}");
        let rt = bytes[i + 2];
        let payload = &bytes[i + 4..i + len];
        match rt {
            STRNAME => s.structures.push(String::from_utf8_lossy(payload).trim_end_matches('\0').to_string()),
            BOUNDARY => in_boundary = true,
            SREF => in_sref = true,
            SNAME => {
                if in_sref {
                    s.srefs.push(String::from_utf8_lossy(payload).trim_end_matches('\0').to_string());
                }
            }
            LAYER => cur_layer = i16::from_be_bytes([payload[0], payload[1]]),
            DATATYPE => cur_dt = i16::from_be_bytes([payload[0], payload[1]]),
            XY => {
                if in_boundary {
                    let coords: Vec<i32> = payload
                        .chunks_exact(4)
                        .map(|c| i32::from_be_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    s.boundaries.push((cur_layer, cur_dt, coords));
                }
            }
            ENDEL => {
                in_boundary = false;
                in_sref = false;
            }
            ENDLIB => break,
            _ => {}
        }
        i += len;
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tech::sg40;

    fn lib_with_cells() -> (Library, Tech) {
        let tech = sg40();
        let mut lib = Library::default();
        let lc = super::super::cells::gc2t_sisi(&tech, false);
        lib.add(lc.layout);
        let mut top = Cell::new("top");
        top.place("a", "gc2t_sisi", 0, 0, Orient::R0);
        top.place("b", "gc2t_sisi", 0, 1320, Orient::Mx);
        lib.add(top);
        (lib, tech)
    }

    #[test]
    fn roundtrip_structures_and_boundaries() {
        let (lib, tech) = lib_with_cells();
        let bytes = write_bytes(&lib, &tech, "testlib");
        let s = read_summary(&bytes).unwrap();
        assert_eq!(s.structures, vec!["gc2t_sisi".to_string(), "top".to_string()]);
        assert_eq!(s.srefs, vec!["gc2t_sisi".to_string(), "gc2t_sisi".to_string()]);
        let n_rects = lib.cells["gc2t_sisi"].rects.len();
        assert_eq!(s.boundaries.len(), n_rects);
        // every boundary is a closed 5-point rectangle
        for (_, _, xy) in &s.boundaries {
            assert_eq!(xy.len(), 10);
            assert_eq!(xy[0], xy[8]);
            assert_eq!(xy[1], xy[9]);
        }
    }

    #[test]
    fn float_format_matches_known_values() {
        // 1e-9 in GDS excess-64: 0x3944B82FA09B5A54 (well-known constant)
        let b = gds_f64(1e-9);
        assert_eq!(b[0], 0x39);
        assert_eq!(b[1], 0x44);
        // 1.0 encodes as exponent 65, mantissa 0x10000000000000
        let one = gds_f64(1.0);
        assert_eq!(one[0], 0x41);
        assert_eq!(one[1], 0x10);
        // sign bit
        assert_eq!(gds_f64(-1.0)[0], 0xc1);
    }

    #[test]
    fn layer_numbers_come_from_tech() {
        let (lib, tech) = lib_with_cells();
        let bytes = write_bytes(&lib, &tech, "t");
        let s = read_summary(&bytes).unwrap();
        let m2_gds = tech.layer_info(crate::tech::LayerRole::Metal2).gds;
        assert!(s.boundaries.iter().any(|(l, _, _)| *l == m2_gds));
    }

    #[test]
    fn write_file_creates_nonempty_gds(){
        let (lib, tech) = lib_with_cells();
        let path = std::env::temp_dir().join("opengcram_test.gds");
        write_file(&lib, &tech, "t", &path).unwrap();
        let meta = std::fs::metadata(&path).unwrap();
        assert!(meta.len() > 100);
        std::fs::remove_file(&path).ok();
    }
}
