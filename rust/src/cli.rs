//! Shared command-line parsing for the `opengcram` binary (hand-rolled
//! args; clap is not in the offline registry).
//!
//! All value parsing is **strict**: a flag whose value does not parse
//! (`--word abc`, `--window-res fast`) or an unknown enumerated name
//! (`--flavor gc-pn`, `--machine a100`) is a hard error carrying the
//! offending string — never a silent fallback to a default.  Defaults
//! apply only when the flag is absent.  (Regression: the pre-PR-4 CLI
//! swallowed bad numbers via `.and_then(parse().ok()).unwrap_or(..)`
//! and mapped any unknown flavor to `GcSiSiNp`.)
//!
//! Every subcommand — including `compose` — parses through these
//! helpers, so new flags inherit the strictness for free.

use crate::compiler::CellFlavor;
use crate::runtime::SharedRuntime;
use crate::tech::Tech;
use crate::variation::{self, VariationModel};
use crate::workloads::{self, CacheLevel, Machine};
use std::path::Path;

/// The value following `name`, if the flag is present.
pub fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

/// Whether the bare flag `name` is present.
pub fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

/// Parse `name`'s value if present; an absent flag yields `default`,
/// an unparseable value is a hard error naming the flag and the
/// offending string.
pub fn parse_or<T: std::str::FromStr>(args: &[String], name: &str, default: T) -> crate::Result<T> {
    match flag_value(args, name) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| {
            anyhow::anyhow!(
                "invalid value for {name}: '{v}' is not a valid {}",
                std::any::type_name::<T>()
            )
        }),
    }
}

/// Parse a `--flavor` spelling; unknown names are hard errors.
pub fn parse_flavor(s: &str) -> crate::Result<CellFlavor> {
    match s {
        "gc-np" => Ok(CellFlavor::GcSiSiNp),
        "gc-nn" => Ok(CellFlavor::GcSiSiNn),
        "os" => Ok(CellFlavor::GcOsOs),
        "sram" => Ok(CellFlavor::Sram6t),
        _ => anyhow::bail!("unknown --flavor '{s}' (expected gc-np|gc-nn|os|sram)"),
    }
}

/// The `--flavor` flag: absent yields `default`, present-but-unknown
/// errors (it used to map to `GcSiSiNp` silently).
pub fn parse_flavor_flag(args: &[String], default: CellFlavor) -> crate::Result<CellFlavor> {
    match flag_value(args, "--flavor") {
        None => Ok(default),
        Some(s) => parse_flavor(&s),
    }
}

/// The `--flavor` spelling of a flavor (round-trips [`parse_flavor`]);
/// the composition report prints these.
pub fn flavor_name(f: CellFlavor) -> &'static str {
    match f {
        CellFlavor::GcSiSiNp => "gc-np",
        CellFlavor::GcSiSiNn => "gc-nn",
        CellFlavor::GcOsOs => "os",
        CellFlavor::Sram6t => "sram",
    }
}

/// Resolve a machine by its `--machine` spelling — shared by the flag
/// parser and the serve protocol (a request's `"machine"` field uses
/// the same names and the same strictness).
pub fn machine_by_name(s: &str) -> crate::Result<&'static Machine> {
    match s {
        "h100" => Ok(&workloads::H100),
        "gt520m" => Ok(&workloads::GT520M),
        other => anyhow::bail!("unknown --machine '{other}' (expected h100|gt520m)"),
    }
}

/// The `--machine` flag (default H100); unknown names error.
pub fn parse_machine(args: &[String]) -> crate::Result<&'static Machine> {
    match flag_value(args, "--machine") {
        None => Ok(&workloads::H100),
        Some(s) => machine_by_name(&s),
    }
}

/// The `--level` flag (default L1); unknown names error.
pub fn parse_level(args: &[String]) -> crate::Result<CacheLevel> {
    match flag_value(args, "--level").as_deref() {
        None | Some("l1") => Ok(CacheLevel::L1),
        Some("l2") => Ok(CacheLevel::L2),
        Some(other) => anyhow::bail!("unknown --level '{other}' (expected l1|l2)"),
    }
}

/// Execution-backend selection (`--backend native|pjrt|auto`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// PJRT when artifacts load, native otherwise (the default).
    Auto,
    /// The in-process EKV solver; needs nothing on disk.
    Native,
    /// The PJRT artifact executor; errors without `artifacts/` and the
    /// linked `xla` crate.
    Pjrt,
}

impl Backend {
    /// Resolve the choice against an artifact directory.  When the
    /// `OPENGCRAM_FAULTS` environment variable holds a fault plan
    /// (see [`crate::runtime::fault::FaultPlan::parse`] for the spec
    /// grammar), the loaded backend is additionally wrapped in
    /// deterministic fault injection — the CI chaos mode; a malformed
    /// spec is a hard error, never silently ignored.
    pub fn load(self, dir: &Path) -> crate::Result<SharedRuntime> {
        let rt = match self {
            Backend::Auto => SharedRuntime::auto(dir),
            Backend::Native => SharedRuntime::native(),
            Backend::Pjrt => SharedRuntime::load(dir)?,
        };
        Ok(match crate::runtime::fault::FaultPlan::from_env()? {
            Some(plan) => rt.with_faults(plan),
            None => rt,
        })
    }
}

/// The `--backend` flag (default `auto`); unknown names error.
pub fn parse_backend(args: &[String]) -> crate::Result<Backend> {
    match flag_value(args, "--backend").as_deref() {
        None | Some("auto") => Ok(Backend::Auto),
        Some("native") => Ok(Backend::Native),
        Some("pjrt") => Ok(Backend::Pjrt),
        Some(other) => anyhow::bail!("unknown --backend '{other}' (expected native|pjrt|auto)"),
    }
}

/// The Monte-Carlo flag family shared by `dse` and `compose`:
/// `--mc [K]` enables variation sampling (K defaults to
/// [`variation::DEFAULT_SAMPLES`]; a bare `--mc` directly followed by
/// another flag keeps the default), `--mc-seed S` reseeds the
/// substream root, `--sigma-vt V` overrides the per-instance VT sigma
/// for **both** device classes, and `--corners tt,ss,..` mixes named
/// tech corners into the samples.  Using any of the dependent flags
/// (including `--yield`) without `--mc` is a hard error — MC knobs
/// must never be silently inert.
pub fn parse_mc(args: &[String], tech: &Tech) -> crate::Result<Option<VariationModel>> {
    if !has_flag(args, "--mc") {
        for f in ["--mc-seed", "--sigma-vt", "--corners", "--yield"] {
            anyhow::ensure!(!has_flag(args, f), "{f} requires --mc");
        }
        return Ok(None);
    }
    let k = match flag_value(args, "--mc") {
        Some(v) if !v.starts_with("--") => v
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid --mc sample count '{v}'"))?,
        _ => variation::DEFAULT_SAMPLES,
    };
    anyhow::ensure!(k >= 1, "--mc needs at least one sample");
    let seed = parse_or(args, "--mc-seed", variation::DEFAULT_SEED)?;
    let mut model = VariationModel::from_tech(tech, k, seed);
    if let Some(v) = flag_value(args, "--sigma-vt") {
        let s: f64 = v
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid --sigma-vt '{v}'"))?;
        anyhow::ensure!(
            s.is_finite() && s >= 0.0,
            "--sigma-vt must be a finite non-negative voltage, got {s}"
        );
        model = model.with_sigma_vt(s);
    }
    if let Some(list) = flag_value(args, "--corners") {
        let mut corners = Vec::new();
        for name in list.split(',') {
            let name = name.trim();
            let c = tech.corner(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown --corners entry '{name}' (tech {} declares: {})",
                    tech.name,
                    tech.corners.iter().map(|c| c.name).collect::<Vec<_>>().join(", ")
                )
            })?;
            corners.push(*c);
        }
        model.corners = corners;
    }
    Ok(Some(model))
}

/// The `--yield` feasibility target in `[0, 1]` (default
/// [`variation::DEFAULT_YIELD_TARGET`]).
pub fn parse_yield(args: &[String]) -> crate::Result<f64> {
    let t: f64 = parse_or(args, "--yield", variation::DEFAULT_YIELD_TARGET)?;
    anyhow::ensure!((0.0..=1.0).contains(&t), "--yield must be in [0, 1], got {t}");
    Ok(t)
}

/// The `--weights delay,area,power` flag: three comma-separated
/// numbers, each validated individually.
pub fn parse_weights(
    args: &[String],
    default: (f64, f64, f64),
) -> crate::Result<(f64, f64, f64)> {
    let s = match flag_value(args, "--weights") {
        None => return Ok(default),
        Some(s) => s,
    };
    let parts: Vec<&str> = s.split(',').collect();
    anyhow::ensure!(
        parts.len() == 3,
        "invalid --weights '{s}': expected three comma-separated numbers (delay,area,power)"
    );
    let mut w = [0.0f64; 3];
    for (slot, part) in w.iter_mut().zip(&parts) {
        *slot = part
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid --weights component '{part}' in '{s}'"))?;
    }
    Ok((w[0], w[1], w[2]))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn numeric_flags_parse_strictly() {
        let args = a(&["--word", "64", "--words", "abc"]);
        assert_eq!(parse_or(&args, "--word", 32usize).unwrap(), 64);
        assert_eq!(parse_or(&args, "--missing", 7usize).unwrap(), 7);
        // regression: '--words abc' used to fall back silently to 32
        let err = parse_or::<usize>(&args, "--words", 32).unwrap_err();
        assert!(err.to_string().contains("abc"), "{err}");
        assert!(err.to_string().contains("--words"), "{err}");
        let err = parse_or::<f64>(&a(&["--window-res", "fast"]), "--window-res", 0.1).unwrap_err();
        assert!(err.to_string().contains("fast"), "{err}");
        assert_eq!(parse_or(&a(&["--window-res", "0.25"]), "--window-res", 0.1).unwrap(), 0.25);
    }

    #[test]
    fn flavor_parsing_rejects_unknown_names() {
        for f in [
            CellFlavor::Sram6t,
            CellFlavor::GcSiSiNp,
            CellFlavor::GcSiSiNn,
            CellFlavor::GcOsOs,
        ] {
            assert_eq!(parse_flavor(flavor_name(f)).unwrap(), f, "round-trip {f:?}");
        }
        // regression: any unknown string used to map to GcSiSiNp
        let err = parse_flavor("gc-pn").unwrap_err();
        assert!(err.to_string().contains("gc-pn"), "{err}");
        assert!(parse_flavor("").is_err());
        // absent flag -> default; present + unknown -> error
        assert_eq!(parse_flavor_flag(&a(&[]), CellFlavor::GcOsOs).unwrap(), CellFlavor::GcOsOs);
        assert!(parse_flavor_flag(&a(&["--flavor", "6t"]), CellFlavor::GcSiSiNp).is_err());
    }

    #[test]
    fn machine_level_weights_parse_strictly() {
        assert_eq!(parse_machine(&a(&[])).unwrap().name, "H100");
        assert_eq!(parse_machine(&a(&["--machine", "gt520m"])).unwrap().name, "GT520M");
        assert!(parse_machine(&a(&["--machine", "a100"])).is_err());
        assert_eq!(parse_level(&a(&[])).unwrap(), CacheLevel::L1);
        assert_eq!(parse_level(&a(&["--level", "l2"])).unwrap(), CacheLevel::L2);
        assert!(parse_level(&a(&["--level", "l3"])).is_err());
        assert_eq!(parse_weights(&a(&[]), (1.0, 0.5, 0.5)).unwrap(), (1.0, 0.5, 0.5));
        assert_eq!(
            parse_weights(&a(&["--weights", "2, 1, 0.25"]), (1.0, 0.5, 0.5)).unwrap(),
            (2.0, 1.0, 0.25)
        );
        let err = parse_weights(&a(&["--weights", "2,x,3"]), (1.0, 0.5, 0.5)).unwrap_err();
        assert!(err.to_string().contains('x'), "{err}");
        assert!(parse_weights(&a(&["--weights", "1,2"]), (1.0, 0.5, 0.5)).is_err());
    }

    #[test]
    fn backend_parsing_is_strict_and_native_loads_anywhere() {
        assert_eq!(parse_backend(&a(&[])).unwrap(), Backend::Auto);
        assert_eq!(parse_backend(&a(&["--backend", "auto"])).unwrap(), Backend::Auto);
        assert_eq!(parse_backend(&a(&["--backend", "native"])).unwrap(), Backend::Native);
        assert_eq!(parse_backend(&a(&["--backend", "pjrt"])).unwrap(), Backend::Pjrt);
        let err = parse_backend(&a(&["--backend", "cuda"])).unwrap_err();
        assert!(err.to_string().contains("cuda"), "{err}");
        // native and auto resolve with no artifacts on disk; explicit
        // pjrt fails cleanly there
        let nowhere = Path::new("/nonexistent-artifacts");
        assert_eq!(Backend::Native.load(nowhere).unwrap().backend_name(), "native");
        assert_eq!(Backend::Auto.load(nowhere).unwrap().backend_name(), "native");
        assert!(Backend::Pjrt.load(nowhere).is_err());
    }

    #[test]
    fn mc_flags_parse_strictly() {
        let t = crate::tech::sg40();
        assert!(parse_mc(&a(&[]), &t).unwrap().is_none());
        // MC-only knobs without --mc are hard errors, never inert
        for f in [&["--sigma-vt", "0.02"][..], &["--yield", "0.9"][..], &["--corners", "ss"][..]] {
            let err = parse_mc(&a(f), &t).unwrap_err();
            assert!(err.to_string().contains("requires --mc"), "{err}");
        }
        let m = parse_mc(&a(&["--mc"]), &t).unwrap().unwrap();
        assert_eq!(m.samples, variation::DEFAULT_SAMPLES);
        assert_eq!(m.seed, variation::DEFAULT_SEED);
        assert_eq!(m.corners.len(), 1, "typical corner only by default");
        // bare --mc directly followed by another flag keeps the default K
        let m = parse_mc(&a(&["--mc", "--backend"]), &t).unwrap().unwrap();
        assert_eq!(m.samples, variation::DEFAULT_SAMPLES);
        let m = parse_mc(
            &a(&["--mc", "256", "--mc-seed", "7", "--sigma-vt", "0.05", "--corners", "tt, ss"]),
            &t,
        )
        .unwrap()
        .unwrap();
        assert_eq!((m.samples, m.seed), (256, 7));
        assert_eq!(m.si.sigma_vt, 0.05);
        assert_eq!(m.os.sigma_vt, 0.05, "--sigma-vt overrides both classes");
        assert_eq!(m.corners.len(), 2);
        assert_eq!(m.corners[1].name, "ss");
        assert!(parse_mc(&a(&["--mc", "abc"]), &t).is_err());
        assert!(parse_mc(&a(&["--mc", "0"]), &t).is_err());
        assert!(parse_mc(&a(&["--mc", "8", "--sigma-vt", "-0.1"]), &t).is_err());
        let err = parse_mc(&a(&["--mc", "8", "--corners", "fff"]), &t).unwrap_err();
        assert!(err.to_string().contains("fff"), "{err}");
        assert_eq!(parse_yield(&a(&[])).unwrap(), variation::DEFAULT_YIELD_TARGET);
        assert_eq!(parse_yield(&a(&["--yield", "0.95"])).unwrap(), 0.95);
        assert!(parse_yield(&a(&["--yield", "1.5"])).is_err());
        assert!(parse_yield(&a(&["--yield", "two-nines"])).is_err());
    }

    #[test]
    fn flag_scanning_basics() {
        let args = a(&["compile", "--word", "16", "--wwlls"]);
        assert_eq!(flag_value(&args, "--word").as_deref(), Some("16"));
        assert_eq!(flag_value(&args, "--words"), None);
        assert!(has_flag(&args, "--wwlls"));
        assert!(!has_flag(&args, "--gds"));
    }
}
