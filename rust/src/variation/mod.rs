//! Monte-Carlo variation and yield-aware feasibility (PR 8).
//!
//! The paper's pitch is that GCRAM retention and speed are *tunable*
//! through transistor design and operating voltage — but real silicon
//! samples those knobs from distributions, so a compiler that only
//! evaluates nominal points overstates feasibility (MCAIMem makes the
//! same argument for GC-vs-SRAM comparisons).  This module makes
//! feasibility statistical:
//!
//! 1. a [`VariationModel`] (per-instance VT sigma, geometry deltas,
//!    VDD droop — per-device-class defaults from
//!    [`crate::tech::Tech::variation_for`], plus a PVT corner mix)
//!    expands each candidate design into `K` sampled variants, each a
//!    [`CharPlan::with_variation`] perturbation of the nominal plan;
//! 2. every variant of every design rides **one mega-batch** through
//!    [`characterize::characterize_plans_health`], so `K x D` samples
//!    pay the grouped-ceiling execution count the coordinator already
//!    guarantees (retention packs to `ceil(K*D/cap)`; write/read pack
//!    per quantized-window bucket) instead of `K*D` executions;
//! 3. the per-design spans reduce to [`YieldStats`]: functional yield
//!    with a 95 % Wilson interval, per-metric mean/sigma/quantiles,
//!    and the demand-joint `P(functional ∧ demand met)` via
//!    [`DesignYield::yield_for`].
//!
//! # Reproducibility
//!
//! Sample `i` of design `d` draws from
//! `Rng::new(seed).split(stream_label(d, i))` — a pure function of the
//! seed and the design's *identity* (not its position in the batch),
//! so yields are bit-reproducible regardless of batch order, config
//! duplication, or worker count ([`crate::util::rng::Rng::split`]
//! never advances the parent stream).  A zero-sigma model produces the
//! identity [`Perturb`] for every sample, and
//! [`CharPlan::with_variation`] maps the identity to the bitwise
//! nominal plan — so zero-sigma Monte-Carlo results are bit-equal to
//! the non-MC path (`tests/variation.rs` pins all of this).
//!
//! # Fault accounting
//!
//! Quarantined variants (the PR-6 fault path: degenerate inputs,
//! non-finite outputs, poisoned rows) count **against** yield as
//! non-functional samples, with their reason kept in
//! [`YieldStats::quarantined`] and in the sweep's [`RunHealth`] —
//! never silently dropped.  `tests/fault.rs` pins that one poisoned
//! variant lowers its design's yield by exactly `1/K` while sibling
//! variants stay bit-identical.

use crate::characterize::{self, calls_for, BankPerf, CharPlan, Perturb, Quarantine};
use crate::compiler::{Bank, CellFlavor, CompileCache, Config, ConfigKey};
use crate::dse::{self, Evaluated};
use crate::runtime::{RunHealth, SharedRuntime};
use crate::tech::{Corner, Tech, VariationDefaults};
use crate::util::rng::Rng;
use crate::workloads::Demand;
use std::collections::{HashMap, HashSet};

/// Default sample count for `--mc` without an explicit K.
pub const DEFAULT_SAMPLES: usize = 64;
/// Default Monte-Carlo seed (any fixed value works; goldens pin it).
pub const DEFAULT_SEED: u64 = 0x0BAD_5EED;
/// Default `--yield` feasibility target.
pub const DEFAULT_YIELD_TARGET: f64 = 0.99;
/// z for the two-sided 95 % Wilson score interval.
pub const WILSON_Z: f64 = 1.959963984540054;

/// The sampled-variation model: how many variants per design, the
/// substream seed, per-device-class mismatch sigmas, and the PVT
/// corner mix each sample draws its systematic shift from.
#[derive(Debug, Clone)]
pub struct VariationModel {
    /// Variants per design (K).
    pub samples: usize,
    /// Root seed; every (design, sample) substream derives from it.
    pub seed: u64,
    /// Mismatch sigmas for FEOL silicon cell devices.
    pub si: VariationDefaults,
    /// Mismatch sigmas for BEOL oxide-semiconductor cell devices.
    pub os: VariationDefaults,
    /// Corners sampled uniformly per instance (die-to-die systematic
    /// shift under the per-instance mismatch).  Must be non-empty;
    /// `[Corner::typical(vdd)]` for mismatch-only sampling.
    pub corners: Vec<Corner>,
}

impl VariationModel {
    /// Model with the node's declared per-class defaults and the
    /// typical corner only.
    pub fn from_tech(tech: &Tech, samples: usize, seed: u64) -> VariationModel {
        VariationModel {
            samples,
            seed,
            si: tech.variation_for("si"),
            os: tech.variation_for("os"),
            corners: vec![Corner::typical(tech.vdd)],
        }
    }

    /// All-zero sigmas at the typical corner: every sample is the
    /// identity perturbation (the zero-sigma bitwise-parity pin).
    pub fn zero(samples: usize, seed: u64, vdd: f64) -> VariationModel {
        let z = VariationDefaults { sigma_vt: 0.0, sigma_geom: 0.0, sigma_vdd: 0.0 };
        VariationModel { samples, seed, si: z, os: z, corners: vec![Corner::typical(vdd)] }
    }

    /// Override the VT sigma for both device classes (CLI `--sigma-vt`).
    pub fn with_sigma_vt(mut self, sigma_vt: f64) -> VariationModel {
        self.si.sigma_vt = sigma_vt;
        self.os.sigma_vt = sigma_vt;
        self
    }

    fn sigmas(&self, flavor: CellFlavor) -> &VariationDefaults {
        if flavor == CellFlavor::GcOsOs {
            &self.os
        } else {
            &self.si
        }
    }

    /// Stable substream label for (design identity, sample index):
    /// built from the config's *fields*, never its batch position, so
    /// the same design draws the same variants anywhere in any sweep.
    pub fn stream_label(cfg: &Config, sample: usize) -> String {
        format!(
            "{:?}/{}x{}/wwlls{}/mux{:?}/vt{:?}#{}",
            cfg.flavor, cfg.word_size, cfg.num_words, cfg.wwlls, cfg.mux_factor, cfg.write_vt, sample
        )
    }

    /// Draw sample `sample`'s perturbation for `cfg`.  Pure: depends
    /// only on (seed, design identity, sample index, sigmas, corners).
    /// With all-zero sigmas and the typical corner this returns the
    /// identity perturbation exactly (`0.0 * z` collapses to `±0.0`,
    /// and `Perturb::is_identity` treats `-0.0` as identity).
    pub fn perturb(&self, tech: &Tech, cfg: &Config, sample: usize) -> Perturb {
        let s = self.sigmas(cfg.flavor);
        let mut r = Rng::new(self.seed).split(&Self::stream_label(cfg, sample));
        let corner = if self.corners.is_empty() {
            Corner::typical(tech.vdd)
        } else {
            self.corners[r.below(self.corners.len())]
        };
        Perturb {
            vt_shift_wr: corner.vt_shift + s.sigma_vt * r.normal(),
            vt_shift_rd: corner.vt_shift + s.sigma_vt * r.normal(),
            kp_scale: corner.kp_scale * (1.0 + s.sigma_geom * r.normal()),
            c_scale: 1.0 + s.sigma_geom * r.normal(),
            vdd_scale: (corner.vdd / tech.vdd) * (1.0 + s.sigma_vdd * r.normal()),
        }
    }
}

/// A binomial yield estimate with its 95 % Wilson score interval.
#[derive(Debug, Clone, Copy)]
pub struct YieldEstimate {
    pub passed: usize,
    pub samples: usize,
    /// Point estimate `passed / samples` (NaN when `samples == 0`).
    pub p: f64,
    pub lo: f64,
    pub hi: f64,
}

/// Wilson score interval for `passed` successes in `samples` trials at
/// critical value `z`.  Unlike the normal approximation it stays
/// inside [0, 1] and behaves at p-hat near 0/1 — exactly the regime a
/// 99 % yield target lives in.
pub fn wilson(passed: usize, samples: usize, z: f64) -> YieldEstimate {
    if samples == 0 {
        return YieldEstimate { passed: 0, samples: 0, p: f64::NAN, lo: 0.0, hi: 1.0 };
    }
    let n = samples as f64;
    let p = passed as f64 / n;
    let z2 = z * z;
    let denom = 1.0 + z2 / n;
    let center = p + z2 / (2.0 * n);
    let half = z * (p * (1.0 - p) / n + z2 / (4.0 * n * n)).sqrt();
    YieldEstimate {
        passed,
        samples,
        p,
        lo: ((center - half) / denom).max(0.0),
        hi: ((center + half) / denom).min(1.0),
    }
}

/// Mean / sigma / quantiles of one metric over the functional samples.
/// Non-finite values propagate into the mean (SRAM retention is
/// infinite by design); NaNs are excluded up front.
#[derive(Debug, Clone, Copy)]
pub struct MetricStats {
    pub mean: f64,
    pub sigma: f64,
    pub q05: f64,
    pub q50: f64,
    pub q95: f64,
}

/// Compute [`MetricStats`] (nearest-rank quantiles).  All-NaN or empty
/// input yields all-NaN stats.
pub fn metric_stats(values: &[f64]) -> MetricStats {
    let mut v: Vec<f64> = values.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return MetricStats {
            mean: f64::NAN,
            sigma: f64::NAN,
            q05: f64::NAN,
            q50: f64::NAN,
            q95: f64::NAN,
        };
    }
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaNs filtered"));
    let n = v.len() as f64;
    let mean = v.iter().sum::<f64>() / n;
    let sigma = (v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n).sqrt();
    let q = |f: f64| v[((n - 1.0) * f).round() as usize];
    MetricStats { mean, sigma, q05: q(0.05), q50: q(0.5), q95: q(0.95) }
}

/// The statistical reduction of one design's K sampled variants.
#[derive(Debug, Clone)]
pub struct YieldStats {
    /// P(electrically functional), Wilson 95 %.  Demand-joint yield
    /// (functional ∧ frequency ∧ retention met) is per-demand — see
    /// [`DesignYield::yield_for`].
    pub functional: YieldEstimate,
    pub f_op_hz: MetricStats,
    pub retention_s: MetricStats,
    pub leakage_w: MetricStats,
    pub stored_one_v: MetricStats,
    /// `(sample index, reason)` for fault-quarantined variants; they
    /// count as failures in every yield figure, never dropped.
    pub quarantined: Vec<(usize, String)>,
}

/// One design's Monte-Carlo outcome: the nominal (unperturbed) point,
/// the K sampled variants in sample order, and their reduction.
#[derive(Debug, Clone)]
pub struct DesignYield {
    pub config: Config,
    pub area_um2: f64,
    /// The unperturbed point — identical to what the non-MC sweep
    /// reports for this design.
    pub nominal: Evaluated,
    /// K sampled variants, index == sample index.
    pub samples: Vec<Evaluated>,
    pub stats: YieldStats,
}

impl DesignYield {
    /// `P(functional ∧ demand met)`: the fraction of samples whose
    /// shmoo verdict passes `d`, with its Wilson 95 % interval.
    /// Quarantined samples never pass, so they count against yield.
    pub fn yield_for(&self, d: &Demand) -> YieldEstimate {
        let k = self.samples.iter().filter(|e| dse::shmoo_verdict(e, d).pass()).count();
        wilson(k, self.samples.len(), WILSON_Z)
    }

    /// Yield-aware shmoo verdict: `Pass` iff the demand-joint yield
    /// point estimate reaches `target`, else the most common failure
    /// verdict among the failing samples (ties break toward the
    /// earlier verdict in quarantine/margin/frequency/retention order).
    pub fn yield_verdict(&self, d: &Demand, target: f64) -> dse::Verdict {
        if self.yield_for(d).p >= target {
            return dse::Verdict::Pass;
        }
        let mut best = dse::Verdict::FailMargin;
        let mut best_n = 0usize;
        for v in [
            dse::Verdict::Quarantined,
            dse::Verdict::FailMargin,
            dse::Verdict::FailFreq,
            dse::Verdict::FailRetention,
        ] {
            let n = self.samples.iter().filter(|e| dse::shmoo_verdict(e, d) == v).count();
            if n > best_n {
                best = v;
                best_n = n;
            }
        }
        best
    }

    /// Yield-adjusted point for Pareto/cost ranking: every perf field
    /// is the mean over *functional* samples (the distribution's
    /// center, not the nominal's optimism), and `functional` holds iff
    /// the functional yield reaches `target`.  Feasibility decisions
    /// should still gate on [`Self::yield_for`] — this point only
    /// ranks the survivors.
    pub fn adjusted(&self, target: f64) -> Evaluated {
        let funcs: Vec<&BankPerf> =
            self.samples.iter().filter(|e| e.perf.functional).map(|e| &e.perf).collect();
        let mean = |f: fn(&BankPerf) -> f64| {
            if funcs.is_empty() {
                f64::NAN
            } else {
                funcs.iter().map(|p| f(p)).sum::<f64>() / funcs.len() as f64
            }
        };
        let perf = BankPerf {
            f_read_hz: mean(|p| p.f_read_hz),
            f_write_hz: mean(|p| p.f_write_hz),
            f_op_hz: mean(|p| p.f_op_hz),
            bandwidth_bps: mean(|p| p.bandwidth_bps),
            retention_s: mean(|p| p.retention_s),
            leakage_w: mean(|p| p.leakage_w),
            e_read_j: mean(|p| p.e_read_j),
            t_decoder_s: mean(|p| p.t_decoder_s),
            t_cell_read_s: mean(|p| p.t_cell_read_s),
            stored_one_v: mean(|p| p.stored_one_v),
            functional: !funcs.is_empty() && self.stats.functional.p >= target,
        };
        Evaluated {
            config: self.config.clone(),
            perf,
            area_um2: self.area_um2,
            quarantine: None,
        }
    }
}

fn to_eval(bank: &Bank, r: &Result<BankPerf, Quarantine>) -> Evaluated {
    match r {
        Ok(p) => Evaluated {
            config: bank.config.clone(),
            perf: *p,
            area_um2: bank.layout.total_area_um2(),
            quarantine: None,
        },
        // same quarantine phrasing as dse's evaluate path, so the
        // zero-sigma parity pin covers quarantined designs too
        Err(q) => Evaluated {
            config: bank.config.clone(),
            perf: BankPerf::quarantined(),
            area_um2: bank.layout.total_area_um2(),
            quarantine: Some(format!("{} stage: {}", q.stage, q.reason)),
        },
    }
}

fn reduce_design(bank: &Bank, span: &[Result<BankPerf, Quarantine>]) -> DesignYield {
    let nominal = to_eval(bank, &span[0]);
    let samples: Vec<Evaluated> = span[1..].iter().map(|r| to_eval(bank, r)).collect();
    let functional = samples.iter().filter(|e| e.perf.functional).count();
    let quarantined: Vec<(usize, String)> = samples
        .iter()
        .enumerate()
        .filter_map(|(i, e)| e.quarantine.clone().map(|q| (i, q)))
        .collect();
    let of = |f: fn(&BankPerf) -> f64| -> Vec<f64> {
        samples.iter().filter(|e| e.perf.functional).map(|e| f(&e.perf)).collect()
    };
    let stats = YieldStats {
        functional: wilson(functional, samples.len(), WILSON_Z),
        f_op_hz: metric_stats(&of(|p| p.f_op_hz)),
        retention_s: metric_stats(&of(|p| p.retention_s)),
        leakage_w: metric_stats(&of(|p| p.leakage_w)),
        stored_one_v: metric_stats(&of(|p| p.stored_one_v)),
        quarantined,
    };
    DesignYield {
        config: bank.config.clone(),
        area_um2: bank.layout.total_area_um2(),
        nominal,
        samples,
        stats,
    }
}

/// Expand every distinct design in `configs` into its nominal point
/// plus `model.samples` sampled variants, run **all** of them as one
/// packed mega-batch, and reduce per design.
///
/// Variant order inside the batch is design-major, `[nominal, sample
/// 0, .., sample K-1]` per design — deterministic, which the fault
/// chaos test uses to aim a poisoned row at one specific variant.
/// Variants share a `ConfigKey` with their design, so this path does
/// **not** use the [`dse::EvalCache`] (a cache hit would collapse
/// distinct samples); the nominal sweep alongside remains cacheable.
/// Structures *are* cacheable — variation perturbs the characterizer
/// inputs, never the geometry — so distinct designs compile through
/// `structs` and a VT-axis MC grid pays the distinct-structure census.
pub fn yield_sweep_health(
    tech: &Tech,
    rt: &SharedRuntime,
    configs: &[Config],
    model: &VariationModel,
    workers: usize,
    window_resolution: f64,
    structs: &CompileCache,
) -> crate::Result<(Vec<DesignYield>, RunHealth)> {
    let mut seen: HashSet<ConfigKey> = HashSet::new();
    let mut distinct: Vec<&Config> = Vec::new();
    for cfg in configs {
        let key = cfg.key();
        if !seen.contains(&key) {
            seen.insert(key);
            distinct.push(cfg);
        }
    }
    let banks: Vec<Bank> = structs.compile_all(tech, &distinct, workers)?;
    let k = model.samples;
    let mut plans: Vec<CharPlan> = Vec::with_capacity(banks.len() * (k + 1));
    let mut labels: Vec<String> = Vec::with_capacity(banks.len() * (k + 1));
    for b in &banks {
        plans.push(CharPlan::with_resolution(tech, b, window_resolution));
        labels.push(format!("{} [nom]", characterize::design_label(b)));
        for i in 0..k {
            let p = model.perturb(tech, &b.config, i);
            plans.push(CharPlan::with_variation(tech, b, window_resolution, &p));
            labels.push(format!("{} [s{i}]", characterize::design_label(b)));
        }
    }
    let (res, health) = characterize::characterize_plans_health(rt, plans, labels)?;
    let mut out = Vec::with_capacity(banks.len());
    let mut off = 0usize;
    for b in &banks {
        let span = &res[off..off + k + 1];
        off += k + 1;
        out.push(reduce_design(b, span));
    }
    Ok((out, health))
}

/// Expected `(write, read, retention)` artifact-execution counts for
/// the [`yield_sweep_health`] mega-batch, computed from the variant
/// plans' own window bits — the grouped-ceiling KPI the statistical
/// tests and `perf_hotpaths` assert against the runtime's real call
/// counters.  Write groups key on the quantized write-window bits,
/// read groups on `(pull_up, read-window bits)` with two read jobs per
/// variant, retention packs everything.
pub fn plan_call_counts(
    tech: &Tech,
    configs: &[Config],
    model: &VariationModel,
    window_resolution: f64,
    write_cap: usize,
    read_cap: usize,
    retention_cap: usize,
) -> crate::Result<(usize, usize, usize)> {
    let mut seen: HashSet<ConfigKey> = HashSet::new();
    let mut wr: HashMap<u64, usize> = HashMap::new();
    let mut rd: HashMap<(bool, u64), usize> = HashMap::new();
    let mut ret = 0usize;
    // VT-axis siblings in the census share one compiled structure
    let structs = CompileCache::new();
    for cfg in configs {
        if !seen.insert(cfg.key()) {
            continue;
        }
        let bank = structs.compile(tech, cfg)?;
        let mut plans = vec![CharPlan::with_resolution(tech, &bank, window_resolution)];
        for i in 0..model.samples {
            plans.push(CharPlan::with_variation(
                tech,
                &bank,
                window_resolution,
                &model.perturb(tech, cfg, i),
            ));
        }
        for p in &plans {
            if let Some((w, r)) = p.window_bits() {
                *wr.entry(w).or_insert(0) += 1;
                *rd.entry((cfg.flavor.pull_up_read(), r)).or_insert(0) += 2;
                ret += 1;
            }
        }
    }
    Ok((
        wr.values().map(|&n| calls_for(n, write_cap)).sum(),
        rd.values().map(|&n| calls_for(n, read_cap)).sum(),
        calls_for(ret, retention_cap),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tech::sg40;
    use crate::workloads::{CacheLevel, TASKS};

    fn demand(f: f64, life: f64) -> Demand {
        Demand {
            task: TASKS[0],
            level: CacheLevel::L1,
            machine: "test",
            read_freq_hz: f,
            lifetime_s: life,
        }
    }

    fn fake_sample(functional: bool, f_op: f64, ret: f64) -> Evaluated {
        Evaluated {
            config: Config::new(32, 32, CellFlavor::GcSiSiNp),
            perf: BankPerf {
                f_read_hz: f_op,
                f_write_hz: f_op,
                f_op_hz: f_op,
                bandwidth_bps: 64.0 * f_op,
                retention_s: ret,
                leakage_w: 1e-7,
                e_read_j: 1e-12,
                t_decoder_s: 1e-10,
                t_cell_read_s: 1e-10,
                stored_one_v: 0.6,
                functional,
            },
            area_um2: 100.0,
            quarantine: None,
        }
    }

    fn dy(samples: Vec<Evaluated>) -> DesignYield {
        let functional = samples.iter().filter(|e| e.perf.functional).count();
        let nominal = fake_sample(true, 1e9, 1e-3);
        let quarantined = samples
            .iter()
            .enumerate()
            .filter_map(|(i, e)| e.quarantine.clone().map(|q| (i, q)))
            .collect();
        let stats = YieldStats {
            functional: wilson(functional, samples.len(), WILSON_Z),
            f_op_hz: metric_stats(
                &samples
                    .iter()
                    .filter(|e| e.perf.functional)
                    .map(|e| e.perf.f_op_hz)
                    .collect::<Vec<_>>(),
            ),
            retention_s: metric_stats(
                &samples
                    .iter()
                    .filter(|e| e.perf.functional)
                    .map(|e| e.perf.retention_s)
                    .collect::<Vec<_>>(),
            ),
            leakage_w: metric_stats(&[1e-7]),
            stored_one_v: metric_stats(&[0.6]),
            quarantined,
        };
        DesignYield {
            config: Config::new(32, 32, CellFlavor::GcSiSiNp),
            area_um2: 100.0,
            nominal,
            samples,
            stats,
        }
    }

    #[test]
    fn wilson_interval_shape() {
        // exact edge cases
        let all = wilson(10, 10, WILSON_Z);
        assert_eq!(all.p, 1.0);
        assert!(all.hi <= 1.0 && all.lo < 1.0 && all.lo > 0.6, "{all:?}");
        let none = wilson(0, 10, WILSON_Z);
        assert_eq!(none.p, 0.0);
        assert!(none.lo >= 0.0 && none.hi > 0.0 && none.hi < 0.4, "{none:?}");
        // half: symmetric around 0.5
        let half = wilson(50, 100, WILSON_Z);
        assert!((half.p - 0.5).abs() < 1e-12);
        assert!(((half.lo + half.hi) / 2.0 - 0.5).abs() < 1e-9, "{half:?}");
        // interval shrinks with n at fixed p-hat
        let small = wilson(5, 10, WILSON_Z);
        let big = wilson(500, 1000, WILSON_Z);
        assert!(big.hi - big.lo < small.hi - small.lo);
        // degenerate n=0 is explicit, not NaN bounds
        let zero = wilson(0, 0, WILSON_Z);
        assert!(zero.p.is_nan() && zero.lo == 0.0 && zero.hi == 1.0);
    }

    #[test]
    fn metric_stats_quantiles_and_inf() {
        let s = metric_stats(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.q50, 3.0);
        assert_eq!(s.q05, 1.0);
        assert_eq!(s.q95, 5.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.sigma - 2.0f64.sqrt()).abs() < 1e-12);
        // SRAM-style infinite retention propagates, NaN is excluded
        let s = metric_stats(&[f64::INFINITY, 1.0, f64::NAN]);
        assert!(s.mean.is_infinite());
        let s = metric_stats(&[]);
        assert!(s.mean.is_nan() && s.q50.is_nan());
    }

    #[test]
    fn yield_for_counts_joint_pass_and_quarantine() {
        let mut q = fake_sample(true, 2e9, 1e-3);
        q.quarantine = Some("write stage: poisoned".into());
        q.perf = BankPerf::quarantined();
        let d = dy(vec![
            fake_sample(true, 2e9, 1e-3),  // pass
            fake_sample(true, 2e9, 1e-6),  // retention fail
            fake_sample(false, 2e9, 1e-3), // margin fail
            q,                             // quarantined: counts against
        ]);
        let est = d.yield_for(&demand(1e9, 1e-4));
        assert_eq!((est.passed, est.samples), (1, 4));
        assert_eq!(d.stats.quarantined.len(), 1);
        // dominant failure: one each of retention/margin/quarantine ->
        // tie breaks toward quarantine (listed first)
        assert_eq!(d.yield_verdict(&demand(1e9, 1e-4), 0.9), dse::Verdict::Quarantined);
        // a lax target passes
        assert_eq!(d.yield_verdict(&demand(1e9, 1e-4), 0.25), dse::Verdict::Pass);
    }

    #[test]
    fn adjusted_means_over_functional_samples_only() {
        let d = dy(vec![
            fake_sample(true, 1e9, 1e-3),
            fake_sample(true, 3e9, 3e-3),
            fake_sample(false, 9e9, 9e-3), // excluded from the means
        ]);
        let adj = d.adjusted(0.5);
        assert!((adj.perf.f_op_hz - 2e9).abs() < 1.0);
        assert!((adj.perf.retention_s - 2e-3).abs() < 1e-9);
        assert!(adj.perf.functional, "2/3 functional >= 0.5 target");
        assert!(!d.adjusted(0.9).perf.functional, "2/3 < 0.9 target");
    }

    #[test]
    fn zero_sigma_model_draws_identity_perturbs() {
        let t = sg40();
        let m = VariationModel::zero(8, 1, t.vdd);
        for flavor in [CellFlavor::GcSiSiNp, CellFlavor::GcSiSiNn, CellFlavor::GcOsOs] {
            let cfg = Config::new(32, 32, flavor);
            for i in 0..8 {
                assert!(m.perturb(&t, &cfg, i).is_identity(), "{flavor:?} sample {i}");
            }
        }
    }

    #[test]
    fn perturb_is_identity_of_design_not_position() {
        let t = sg40();
        let m = VariationModel::from_tech(&t, 4, 7);
        let a = Config::new(32, 32, CellFlavor::GcSiSiNp);
        let b = Config::new(64, 64, CellFlavor::GcSiSiNp);
        // same (design, sample) -> same perturbation, draw order free
        let pa2 = m.perturb(&t, &a, 2);
        let _ = m.perturb(&t, &b, 0);
        assert_eq!(m.perturb(&t, &a, 2), pa2);
        // different samples and different designs draw differently
        assert_ne!(m.perturb(&t, &a, 0), m.perturb(&t, &a, 1));
        assert_ne!(m.perturb(&t, &a, 0), m.perturb(&t, &b, 0));
        // sigma scale: OS class declared wider than Si on sg40
        assert!(m.os.sigma_vt > m.si.sigma_vt);
    }

    #[test]
    fn corner_mix_shifts_samples_systematically() {
        let t = sg40();
        let mut m = VariationModel::zero(64, 3, t.vdd);
        m.corners = vec![*t.corner("ss").unwrap()];
        let cfg = Config::new(32, 32, CellFlavor::GcSiSiNp);
        for i in 0..8 {
            let p = m.perturb(&t, &cfg, i);
            assert!(!p.is_identity());
            assert_eq!(p.vt_shift_wr, 0.04, "ss VT shift, zero mismatch sigma");
            assert_eq!(p.kp_scale, 0.87);
            assert!((p.vdd_scale - 0.99 / t.vdd).abs() < 1e-12);
        }
    }
}
